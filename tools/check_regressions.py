#!/usr/bin/env python
"""CI regression gate: fail on *new* test failures, not pre-existing ones.

Runs pytest with the given arguments, collects failing test ids from the
junit XML, and compares them against the allowlist in
``tests/known_failures.txt`` (one ``path::testid`` per line, ``#`` comments).
Exit code is non-zero only when a failure is NOT on the allowlist, so a
known-bad test never masks a fresh regression -- and stale allowlist entries
(now passing) are reported so the list shrinks over time.

    python tools/check_regressions.py -- -m "not slow"
    python tools/check_regressions.py --baseline tests/known_failures.txt -- -q
"""
from __future__ import annotations

import argparse
import os
import subprocess
import sys
import tempfile
import xml.etree.ElementTree as ET

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def load_baseline(path: str) -> set:
    if not os.path.exists(path):
        return set()
    out = set()
    with open(path) as fh:
        for line in fh:
            line = line.strip()
            if line and not line.startswith("#"):
                out.add(line)
    return out


def classname_to_id(cls: str, name: str, repo: str = REPO) -> str:
    """Map a junit (classname, name) pair back to a pytest node id.

    The junit ``classname`` is the dotted module path PLUS any containing
    test classes (``tests.test_x.TestFoo`` for
    ``tests/test_x.py::TestFoo::test_bar``), so blindly replacing dots with
    slashes manufactures paths like ``tests/test_x/TestFoo.py`` that can
    never match an allowlist entry.  Resolve instead by finding the longest
    dotted prefix that is an actual ``.py`` file on disk and treating the
    remaining segments as ``::``-joined class qualifiers; fall back to the
    whole-classname-is-the-module mapping when nothing exists (junit from a
    different tree).
    """
    if not cls:
        return name
    parts = cls.split(".")
    for k in range(len(parts), 0, -1):
        path = "/".join(parts[:k]) + ".py"
        if os.path.exists(os.path.join(repo, path)):
            return "::".join([path] + parts[k:] + [name])
    return "/".join(parts) + f".py::{name}"


def failed_ids(junit_path: str) -> set:
    tree = ET.parse(junit_path)
    out = set()
    for case in tree.iter("testcase"):
        if case.find("failure") is not None or case.find("error") is not None:
            out.add(classname_to_id(case.get("classname", ""),
                                    case.get("name", "")))
    return out


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--baseline",
                    default=os.path.join(REPO, "tests", "known_failures.txt"))
    ap.add_argument("pytest_args", nargs="*",
                    help="arguments forwarded to pytest (after --)")
    args = ap.parse_args()

    with tempfile.TemporaryDirectory() as tmp:
        junit = os.path.join(tmp, "junit.xml")
        cmd = [sys.executable, "-m", "pytest", f"--junitxml={junit}",
               *args.pytest_args]
        print("+", " ".join(cmd), flush=True)
        proc = subprocess.run(cmd, cwd=REPO)
        if not os.path.exists(junit):
            print("check_regressions: pytest produced no junit xml "
                  f"(exit {proc.returncode})")
            return proc.returncode or 1
        failures = failed_ids(junit)
        # Exit codes other than 0 (all passed) / 1 (some tests failed) mean
        # the run itself is unusable -- no tests collected (5), usage error
        # (4), internal error (3), interrupted (2).  A failure-free junit
        # from such a run must NOT turn CI green.
        if proc.returncode not in (0, 1):
            print(f"check_regressions: pytest exit {proc.returncode} "
                  "(not a pass/fail outcome) -- propagating.")
            return proc.returncode

    known = load_baseline(args.baseline)
    new = sorted(f for f in failures if f not in known)
    stale = sorted(k for k in known if k not in failures)
    expected = sorted(f for f in failures if f in known)

    if expected:
        print(f"\n{len(expected)} known failure(s) (allowlisted):")
        for f in expected:
            print(f"  KNOWN {f}")
    if stale:
        print(f"\n{len(stale)} allowlist entr(ies) now pass -- prune "
              f"{args.baseline}:")
        for f in stale:
            print(f"  STALE {f}")
    if new:
        print(f"\n{len(new)} NEW failure(s):")
        for f in new:
            print(f"  NEW   {f}")
        return 1
    print("\ncheck_regressions: no new failures.")
    return 0


if __name__ == "__main__":
    sys.exit(main())
