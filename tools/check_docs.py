#!/usr/bin/env python
"""Doc-integrity gate: DESIGN.md references + runnable quickstart snippets.

Two checks, both CI-enforced (see .github/workflows/ci.yml):

1. **Reference integrity** -- every ``DESIGN.md section N`` citation in the
   source tree (``src/``, ``benchmarks/``, ``examples/``, ``tools/``,
   ``tests/``) must resolve to a numbered heading in ``DESIGN.md``
   (``## N. ...``).  A docstring citing a section that does not exist -- the
   pre-PR-5 state of the whole repo -- fails the build.

2. **Snippet smoke** -- quickstart code is executed, not trusted:

   * fenced blocks tagged ``python doctest`` in ``README.md``, ``DESIGN.md``
     and ``docs/*.md`` must be self-contained and are exec'd standalone;
   * the literal blocks following ``Usage::`` / ``Quickstart::`` in the
     ``repro.engine`` and ``repro.solvers`` module docstrings are exec'd
     with a small prologue namespace (a 66^2 SPD system, inputs, and a local
     epiram engine -- the free variables those snippets document against).

Run locally:

    PYTHONPATH=src python tools/check_docs.py
"""
from __future__ import annotations

import io
import pathlib
import re
import sys
import textwrap
import traceback
from contextlib import redirect_stdout
from typing import Dict, List, Tuple

REPO = pathlib.Path(__file__).resolve().parent.parent
DESIGN = REPO / "DESIGN.md"

REF_RE = re.compile(r"DESIGN\.md\s+section\s+(\d+)")
HEADING_RE = re.compile(r"^#{1,6}\s*(\d+)\.\s+\S", re.MULTILINE)
FENCE_RE = re.compile(r"^```python doctest\s*$(.*?)^```\s*$",
                      re.MULTILINE | re.DOTALL)

SOURCE_DIRS = ("src", "benchmarks", "examples", "tools", "tests")
SNIPPET_DOCS = ("README.md", "DESIGN.md", "docs")
DOCSTRING_MODULES = ("repro.engine", "repro.solvers")
SNIPPET_MARKERS = ("Usage::", "Quickstart::")

# Free variables the docstring snippets are documented against: a small SPD
# system on the paper's 66x66 cell, inputs, and a programmed local engine.
PROLOGUE = textwrap.dedent("""
    import jax, jax.numpy as jnp
    from repro.core import CrossbarConfig, MCAGeometry, get_device
    from repro.engine import AnalogEngine
    key = jax.random.PRNGKey(0)
    _r = jax.random.normal(key, (66, 66), jnp.float32) / 66
    a = _r + _r.T + 2.0 * jnp.eye(66, dtype=jnp.float32)
    x = x1 = x2 = x3 = jnp.ones((66,), jnp.float32)
    b = a @ x
    cfg = CrossbarConfig(device=get_device("epiram"),
                         geom=MCAGeometry(1, 1, 66, 66), k_iters=5, ec=True)
    engine = AnalogEngine(cfg)
""")


def check_design_references() -> List[str]:
    """Every `DESIGN.md section N` in the tree resolves to a heading."""
    errors: List[str] = []
    if not DESIGN.exists():
        return [f"{DESIGN} does not exist"]
    sections = set(HEADING_RE.findall(DESIGN.read_text()))
    refs: Dict[str, List[str]] = {}
    for d in SOURCE_DIRS:
        for path in sorted((REPO / d).rglob("*.py")):
            try:
                text = path.read_text()
            except UnicodeDecodeError:  # pragma: no cover
                continue
            for num in REF_RE.findall(text):
                refs.setdefault(num, []).append(
                    str(path.relative_to(REPO)))
    for num, where in sorted(refs.items()):
        if num not in sections:
            errors.append(
                f"DESIGN.md section {num} cited by {', '.join(where)} "
                f"but DESIGN.md has no heading '## {num}. ...' "
                f"(found sections: {sorted(sections)})")
    n_refs = sum(len(v) for v in refs.values())
    print(f"[design-refs] {n_refs} references to sections "
          f"{sorted(refs)} -- all resolve"
          if not errors else f"[design-refs] {len(errors)} broken")
    return errors


def _run_snippet(code: str, label: str, ns: dict) -> List[str]:
    out = io.StringIO()
    try:
        with redirect_stdout(out):
            exec(compile(code, label, "exec"), ns)
    except Exception:
        return [f"{label} failed:\n{textwrap.indent(traceback.format_exc(), '  ')}"]
    print(f"[snippet] {label} OK")
    return []


def iter_fenced_snippets() -> List[Tuple[str, str]]:
    """(label, code) for every ```python doctest``` block in the doc set."""
    files: List[pathlib.Path] = []
    for entry in SNIPPET_DOCS:
        p = REPO / entry
        files += sorted(p.rglob("*.md")) if p.is_dir() else [p]
    out = []
    for path in files:
        for i, m in enumerate(FENCE_RE.finditer(path.read_text())):
            out.append((f"{path.relative_to(REPO)}[{i}]", m.group(1)))
    return out


def iter_docstring_snippets() -> List[Tuple[str, str]]:
    """(label, code) for the Usage::/Quickstart:: blocks of the API docs."""
    import importlib
    out = []
    for modname in DOCSTRING_MODULES:
        doc = importlib.import_module(modname).__doc__ or ""
        lines = doc.splitlines()
        for idx, line in enumerate(lines):
            if line.strip() not in SNIPPET_MARKERS:
                continue
            block: List[str] = []
            for follower in lines[idx + 1:]:
                if follower.strip() and not follower.startswith("    "):
                    break
                block.append(follower)
            code = textwrap.dedent("\n".join(block)).strip("\n")
            if code:
                out.append((f"{modname}:{line.strip()}", code))
    return out


def check_snippets() -> List[str]:
    errors: List[str] = []
    for label, code in iter_fenced_snippets():
        # fenced doctest blocks must be self-contained: fresh namespace
        errors += _run_snippet(code, label, {"__name__": "__doc_snippet__"})
    ns = {"__name__": "__doc_snippet__"}
    exec(compile(PROLOGUE, "<prologue>", "exec"), ns)
    for label, code in iter_docstring_snippets():
        # docstring snippets share the documented prologue namespace
        errors += _run_snippet(code, label, ns)
    return errors


def main() -> int:
    errors = check_design_references()
    errors += check_snippets()
    if errors:
        print("\n".join(["", "DOC INTEGRITY FAILURES:"] + errors),
              file=sys.stderr)
        return 1
    print("doc integrity OK")
    return 0


if __name__ == "__main__":
    sys.exit(main())
