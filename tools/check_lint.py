#!/usr/bin/env python
"""Lint gate: ruff + mypy over ``src/`` (configs in pyproject.toml).

Both tools are optional at runtime so the gate degrades gracefully in the
hermetic test container (no installs available there): whatever is present
runs; whatever is absent is skipped with a note. CI installs both, so the
full gate runs on every push. Either way a built-in AST fallback always
runs: syntax errors and unused imports in ``src/`` fail the build even with
no linters installed.

Usage:

    PYTHONPATH=src python tools/check_lint.py
"""
from __future__ import annotations

import ast
import pathlib
import shutil
import subprocess
import sys
from typing import List

REPO = pathlib.Path(__file__).resolve().parent.parent
SRC = REPO / "src"


def run_tool(name: str, args: List[str]) -> bool | None:
    """Run an external linter; returns None when it is not installed."""
    if shutil.which(name) is None:
        print(f"[lint] {name}: not installed, skipped (CI runs it)")
        return None
    proc = subprocess.run([name, *args], cwd=REPO)
    status = "OK" if proc.returncode == 0 else f"FAILED ({proc.returncode})"
    print(f"[lint] {name}: {status}")
    return proc.returncode == 0


def _used_names(tree: ast.AST) -> set:
    used = set()
    for node in ast.walk(tree):
        if isinstance(node, ast.Name):
            used.add(node.id)
        elif isinstance(node, ast.Attribute):
            # dotted use: collect the root name (``np`` of ``np.prod``)
            inner = node
            while isinstance(inner, ast.Attribute):
                inner = inner.value
            if isinstance(inner, ast.Name):
                used.add(inner.id)
    return used


def ast_fallback() -> List[str]:
    """Syntax + unused-import sweep over src/ with the stdlib only."""
    errors: List[str] = []
    for path in sorted(SRC.rglob("*.py")):
        rel = path.relative_to(REPO)
        try:
            tree = ast.parse(path.read_text(), filename=str(rel))
        except SyntaxError as e:
            errors.append(f"{rel}:{e.lineno}: syntax error: {e.msg}")
            continue
        if path.name == "__init__.py":
            continue  # re-export hubs import for the namespace
        source = path.read_text()
        used = _used_names(tree)
        for node in ast.walk(tree):
            if isinstance(node, ast.Import):
                names = [(a.asname or a.name.split(".")[0], a) for a in node.names]
            elif isinstance(node, ast.ImportFrom):
                # __future__ has side effects; typing-only imports may live
                # purely inside string annotations -- ruff handles those.
                if node.module in ("__future__", "typing"):
                    continue
                names = [(a.asname or a.name, a) for a in node.names
                         if a.name != "*"]
            else:
                continue
            for bound, alias in names:
                if bound in used or bound.startswith("_"):
                    continue
                if f'"{bound}"' in source or f"'{bound}'" in source:
                    continue  # __all__ / getattr-style references
                errors.append(
                    f"{rel}:{node.lineno}: unused import '{bound}'")
    return errors


def main() -> int:
    failed = False
    for name, args in (("ruff", ["check", "src"]),
                       ("mypy", ["src/repro"])):
        ok = run_tool(name, args)
        if ok is False:
            failed = True

    errors = ast_fallback()
    if errors:
        print("\n".join(["", "AST LINT FAILURES:"] + errors), file=sys.stderr)
        failed = True
    else:
        n = len(list(SRC.rglob("*.py")))
        print(f"[lint] ast fallback: OK ({n} files)")
    return 1 if failed else 0


if __name__ == "__main__":
    sys.exit(main())
