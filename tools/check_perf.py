#!/usr/bin/env python
"""Perf-regression gate: fresh benchmark rows vs the checked-in baselines.

Re-runs a benchmark module in its quick/smoke mode and compares every row
that also exists in the checked-in ``BENCH_<name>.json`` (matched by row
``name``) on two axes:

  * **dispatch counts** -- every ``dispatch*``-keyed field must match the
    baseline EXACTLY.  Dispatch structure is topology-independent: a PR
    that silently re-introduces per-layer or per-block launches fails here
    even on a machine whose wall-clock numbers are incomparable.
  * **``us_per_call``** -- fresh timing must stay within ``--tolerance``
    (default 3x) of the baseline, but ONLY when :func:`run_metadata`
    fingerprints match (backend, device count, XLA flags).  On a different
    topology the timing check is skipped with a notice instead of producing
    a false verdict -- the guard the BENCH metadata stamp exists for.

Rows present only in the fresh run (or only in the full-sweep baseline --
smoke sweeps a subset) are ignored: the gate compares trajectories, it does
not require identical sweeps.

Usage:

    PYTHONPATH=src python tools/check_perf.py                   # all gated
    PYTHONPATH=src python tools/check_perf.py --bench model_dispatch
    PYTHONPATH=src python tools/check_perf.py --tolerance 5.0
"""
from __future__ import annotations

import argparse
import importlib
import json
import os
import pathlib
import sys

ROOT = pathlib.Path(__file__).resolve().parent.parent
sys.path.insert(0, str(ROOT / "src"))
sys.path.insert(0, str(ROOT))

#: benchmarks gated here: checked-in baseline -> module with a run(quick=)
#: entry point whose quick rows share names with the full-sweep baseline.
GATED = {
    "model_dispatch": "benchmarks.model_dispatch",
    "streamed_scaling": "benchmarks.streamed_scaling",
}


def _baseline(name: str) -> dict:
    path = ROOT / f"BENCH_{name}.json"
    with open(path) as f:
        return json.load(f)


def _dispatch_keys(row: dict):
    return sorted(k for k in row if "dispatch" in k)


def check_bench(name: str, module: str, tolerance: float) -> list:
    """Returns a list of violation strings for one gated benchmark."""
    from benchmarks.common import run_metadata

    base = _baseline(name)
    fresh_rows = importlib.import_module(module).run(quick=True)
    base_rows = {r["name"]: r for r in base["rows"]}
    meta_now, meta_base = run_metadata(), base["metadata"]
    same_topology = meta_now == meta_base

    violations = []
    compared = 0
    for row in fresh_rows:
        ref = base_rows.get(row["name"])
        if ref is None:
            continue
        compared += 1
        for k in _dispatch_keys(ref):
            if row.get(k) != ref[k]:
                violations.append(
                    f"{name}/{row['name']}: {k} = {row.get(k)} "
                    f"(baseline {ref[k]}) -- dispatch structure changed")
        if same_topology and ref.get("us_per_call") and row.get("us_per_call"):
            ratio = row["us_per_call"] / ref["us_per_call"]
            if ratio > tolerance:
                violations.append(
                    f"{name}/{row['name']}: us_per_call {row['us_per_call']} "
                    f"is {ratio:.1f}x baseline {ref['us_per_call']} "
                    f"(> tolerance {tolerance}x)")
    if not compared:
        violations.append(
            f"{name}: no fresh row matches the baseline -- sweep renamed?")
    if not same_topology:
        print(f"[perf] {name}: topology differs from baseline "
              f"({meta_now} vs {meta_base}); timing check skipped, "
              f"dispatch counts still gated")
    print(f"[perf] {name}: {compared} rows compared, "
          f"{len(violations)} violation(s)")
    return violations


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--bench", default=None,
                    help="gate only this benchmark (default: all gated)")
    ap.add_argument("--tolerance", type=float, default=3.0,
                    help="max fresh/baseline us_per_call ratio (same "
                         "topology only)")
    args = ap.parse_args()

    os.environ.setdefault("JAX_PLATFORMS", "cpu")
    names = [args.bench] if args.bench else sorted(GATED)
    violations = []
    for name in names:
        if name not in GATED:
            print(f"[perf] unknown benchmark {name!r}; gated: "
                  f"{sorted(GATED)}")
            return 2
        violations += check_bench(name, GATED[name], args.tolerance)
    for v in violations:
        print(f"[perf] FAIL {v}")
    if violations:
        return 1
    print("perf OK")
    return 0


if __name__ == "__main__":
    sys.exit(main())
