#!/usr/bin/env python
"""Invariant gate: static verifier passes over every registered pipeline.

Runs the five jaxpr passes of ``repro.analysis.verify`` (AvalBound,
DispatchCount, KeyReuse, PrecisionLint, CollectiveAudit) over every
pipeline in ``repro.analysis.pipelines`` and compares the measured
structural fingerprint -- largest aval, top-level dispatch counts,
trace-time producer invocations, PRNG consumption census, collective
census -- against the checked-in ``INVARIANTS.json`` manifest.  A PR
that materializes an A-sized aval, adds a dispatch, reuses a key,
drops to f16 in a carry, or widens a collective fails here before any
numeric test could notice.

Nothing numeric runs: pipelines are traced with ShapeDtypeStruct
placeholders (building a spec may program one small resident image).
The process forces 8 host devices so the 2x4-mesh entries verify on
any machine, exactly as in CI.

Usage:

    PYTHONPATH=src python tools/check_invariants.py            # gate
    PYTHONPATH=src python tools/check_invariants.py --update   # re-baseline
    PYTHONPATH=src python tools/check_invariants.py --report out.json

``--update`` rewrites the manifest after an *intentional* pipeline
change -- commit the diff and say why in the PR.  ``--report`` writes
the full per-pass summaries (uploaded as a CI artifact).
See docs/analysis.md and DESIGN.md section 10.
"""
from __future__ import annotations

import argparse
import json
import os
import pathlib
import sys

# 8 host devices BEFORE importing jax: the 2x4-mesh entries must verify
# identically on a laptop, the CI runner, and a real multi-device host.
_FLAG = "--xla_force_host_platform_device_count=8"
if _FLAG not in os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") + " " + _FLAG).strip()

REPO = pathlib.Path(__file__).resolve().parent.parent
MANIFEST = REPO / "INVARIANTS.json"


def run_pipelines():
    from repro.analysis import pipelines as P
    rows = {}
    reports_out = {}
    for spec in P.available_pipelines():
        reports = P.verify_pipeline(spec)
        rows[spec.name] = P.manifest_record(spec, reports)
        reports_out[spec.name] = {
            name: {"ok": r.ok, "summary": r.summary,
                   "violations": [str(v) for v in r.violations]}
            for name, r in reports.items()}
        status = "ok" if not rows[spec.name]["violations"] else "FAIL"
        print(f"[invariants] {spec.name}: {status} "
              f"max_elements={rows[spec.name]['max_elements']} "
              f"top_level={rows[spec.name]['top_level_eqns']}")
    return rows, reports_out


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--update", action="store_true",
                    help="rewrite INVARIANTS.json from the measured values")
    ap.add_argument("--report", metavar="PATH",
                    help="write full per-pass report JSON (CI artifact)")
    args = ap.parse_args()

    rows, reports = run_pipelines()
    if args.report:
        pathlib.Path(args.report).write_text(
            json.dumps(reports, indent=2, sort_keys=True) + "\n")
        print(f"[invariants] report written to {args.report}")

    errors = []
    for name, row in rows.items():
        for v in row["violations"]:
            errors.append(f"{name}: {v}")

    if args.update:
        MANIFEST.write_text(
            json.dumps(rows, indent=2, sort_keys=True) + "\n")
        print(f"[invariants] manifest rewritten: {MANIFEST.name} "
              f"({len(rows)} pipelines)")
        if errors:
            print("\n".join(["", "PASS VIOLATIONS (manifest written anyway, "
                             "fix before committing):"] + errors),
                  file=sys.stderr)
            return 1
        return 0

    if not MANIFEST.exists():
        errors.append(
            f"{MANIFEST.name} missing -- generate it with --update and "
            "commit it")
        stored = {}
    else:
        stored = json.loads(MANIFEST.read_text())

    for name in sorted(set(stored) - set(rows)):
        errors.append(f"{name}: in manifest but not registered/runnable")
    for name in sorted(set(rows) - set(stored)):
        errors.append(f"{name}: registered but missing from manifest "
                      "(run --update)")
    for name in sorted(set(rows) & set(stored)):
        got, want = rows[name], stored[name]
        for key in sorted(set(got) | set(want)):
            if got.get(key) != want.get(key):
                errors.append(
                    f"{name}.{key}: measured {got.get(key)!r} != manifest "
                    f"{want.get(key)!r} (intentional? run --update and "
                    "explain in the PR)")

    if errors:
        print("\n".join(["", "INVARIANT FAILURES:"] + errors), file=sys.stderr)
        return 1
    print(f"invariants OK ({len(rows)} pipelines, 5 passes each)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
