"""Paper Figs. 2-3 (and S1-S2): adjustableWriteandVerify iteration sweep
k = 0..20, with and without the two-tier EC, on Iperturb and bcsstk02.

Expected trends (validated in tests/test_paper_claims.py):
  * error falls with k and plateaus -- at k~2 for TaOx/AlOx/EpiRAM and later
    (k~11) for Ag-aSi (nonlinearity-limited verify gain);
  * E_w and L_w grow linearly in k (passes = k+1);
  * the EC curves sit about an order of magnitude below the raw curves.
"""
from __future__ import annotations

from typing import Dict, List

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import CrossbarConfig, MCAGeometry, corrected_mvm, get_device, rel_l2
from repro.core.matrices import make_iperturb, paper_matrix

GEOM_66 = MCAGeometry(tile_rows=1, tile_cols=1, cell_rows=66, cell_cols=66)
DEVICES = ["epiram", "ag-si", "alox-hfo2", "taox-hfox"]


def run(quick: bool = True) -> List[Dict]:
    ks = [0, 1, 2, 5, 11, 20] if quick else list(range(21))
    reps = 8 if quick else 100
    mats = [("iperturb", jnp.asarray(make_iperturb(66), jnp.float32))]
    if not quick:
        mats.append(("bcsstk02", jnp.asarray(paper_matrix("bcsstk02"), jnp.float32)))
    x = jax.random.normal(jax.random.PRNGKey(7), (66,))
    rows = []
    for mname, a in mats:
        b = a @ x
        for dev in DEVICES:
            for ec in (False, True):
                for k in ks:
                    cfg = CrossbarConfig(device=get_device(dev), geom=GEOM_66,
                                         k_iters=k, ec=ec)
                    fn = jax.jit(lambda kk: corrected_mvm(a, x, kk, cfg))
                    errs = []
                    stats = None
                    for r in range(reps):
                        kk = jax.random.fold_in(
                            jax.random.PRNGKey(1000 * k + r),
                            hash(dev) % (2 ** 30))
                        y, stats = fn(kk)
                        errs.append(float(rel_l2(y, b)))
                    rows.append({
                        "name": f"wv/{mname}/{dev}/{'ec' if ec else 'raw'}/k{k}",
                        "eps_l2": float(np.mean(errs)),
                        "E_w": float(stats.energy_j),
                        "L_w": float(stats.latency_s),
                    })
    return rows


if __name__ == "__main__":
    from .common import emit
    emit(run())
