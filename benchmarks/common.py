"""Shared benchmark utilities: timing, CSV rows, and JSON metadata."""
from __future__ import annotations

import os
import time
from typing import Callable, Dict, List

import jax


def run_metadata() -> Dict:
    """Environment fingerprint every benchmark JSON artifact must embed.

    Records the *initialized* device count and the ``XLA_FLAGS`` that shaped
    it: the scaling benchmarks force an 8-host-device backend at import
    (``--xla_force_host_platform_device_count=8``), which would otherwise
    silently confound a future perf-baseline refresh comparing against
    numbers collected under a different device topology (the ROADMAP item).
    """
    return {
        "backend": jax.default_backend(),
        "device_count": jax.device_count(),
        "xla_flags": os.environ.get("XLA_FLAGS", ""),
        "jax_version": jax.__version__,
    }


def time_call(fn: Callable, *args, warmup: int = 1, iters: int = 3) -> float:
    """Median wall time (us) of a jax callable (block_until_ready)."""
    for _ in range(warmup):
        out = fn(*args)
        jax.block_until_ready(out)
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        out = fn(*args)
        jax.block_until_ready(out)
        times.append((time.perf_counter() - t0) * 1e6)
    times.sort()
    return times[len(times) // 2]


def emit(rows: List[Dict]) -> None:
    for r in rows:
        name = r.pop("name")
        us = r.pop("us_per_call", "")
        derived = ";".join(f"{k}={v}" for k, v in r.items())
        print(f"{name},{us},{derived}")
