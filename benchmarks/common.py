"""Shared benchmark utilities: timing + CSV rows."""
from __future__ import annotations

import time
from typing import Callable, Dict, List

import jax


def time_call(fn: Callable, *args, warmup: int = 1, iters: int = 3) -> float:
    """Median wall time (us) of a jax callable (block_until_ready)."""
    for _ in range(warmup):
        out = fn(*args)
        jax.block_until_ready(out)
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        out = fn(*args)
        jax.block_until_ready(out)
        times.append((time.perf_counter() - t0) * 1e6)
    times.sort()
    return times[len(times) // 2]


def emit(rows: List[Dict]) -> None:
    for r in rows:
        name = r.pop("name")
        us = r.pop("us_per_call", "")
        derived = ";".join(f"{k}={v}" for k, v in r.items())
        print(f"{name},{us},{derived}")
