"""Whole-model dispatch: grouped single-launch forward vs per-layer loop.

The tentpole claim of the grouped-execution PR (DESIGN.md section 13): a
whole analog model forward -- L same-geometry layers -- executes as ONE
device dispatch through :class:`~repro.engine.AnalogMatrixGroup` instead of
L per-layer dispatches.  This benchmark sweeps layers-per-group x arch shape
and reports, for identical per-member keys:

  * ``chain``   -- L square layers chained activation-to-logits through
    ``engine.chain_mvm`` (ONE ``lax.scan`` dispatch) vs a Python loop of L
    solo ``engine.mvm`` calls with the same relu between layers;
  * ``experts`` -- L parallel expert kernels (the MoE pattern) executed by
    one grouped broadcast MVM vs L solo MVMs;
  * dispatch counts for both paths (grouped is 1 by construction -- the
    DispatchCount invariant pins it -- per-layer is L), their ratio, the
    wall-clock speedup, and grouped-vs-solo parity (``rel_l2``).

Results land in ``BENCH_model_dispatch.json`` at the repo root (checked in;
``tools/check_perf.py`` gates dispatch counts and timing against it).

    PYTHONPATH=src python -m benchmarks.model_dispatch            # full sweep
    PYTHONPATH=src python -m benchmarks.model_dispatch --smoke    # CI fast job
"""
from __future__ import annotations

import argparse
import json
import os
import tempfile
from typing import Dict, List

import jax
import jax.numpy as jnp

from repro.core import CrossbarConfig, MCAGeometry, get_device, rel_l2
from repro.engine import AnalogEngine

from .common import run_metadata, time_call

CAP = 32                                   # capacity block edge (1x1 tile MCA)
GEOM = MCAGeometry(tile_rows=1, tile_cols=1, cell_rows=CAP, cell_cols=CAP)
LAYERS_FULL = [2, 4, 8, 16]
LAYERS_SMOKE = [2, 8]
ARCHS_FULL = {"mlp128": 128, "mlp256": 256}     # layer width d (square d x d)
ARCHS_SMOKE = {"mlp128": 128}
OUT_JSON = os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "BENCH_model_dispatch.json")


def _solo_handles(engine: AnalogEngine, stack: jnp.ndarray, key: jax.Array):
    """Per-layer handles under the group's member keys (fold g of key)."""
    return [engine.program(stack[g], jax.random.fold_in(key, g))
            for g in range(stack.shape[0])]


def _bench_chain(arch: str, d: int, L: int, cfg: CrossbarConfig,
                 iters: int) -> Dict:
    """Whole-model forward: L chained square layers, relu between members."""
    key = jax.random.fold_in(jax.random.PRNGKey(13), d * 1000 + L)
    stack = jax.random.normal(key, (L, d, d), jnp.float32) / float(d)
    x = jax.random.normal(jax.random.fold_in(key, 1), (d,))
    k_mvm = jax.random.fold_in(key, 2)

    engine = AnalogEngine(cfg)
    G = engine.program_group(stack, key)
    handles = _solo_handles(engine, stack, key)

    def solo_forward():
        h = x
        for g, A in enumerate(handles):
            h = jax.nn.relu(engine.mvm(A, h, key=jax.random.fold_in(k_mvm, g)))
        return h

    us_group = time_call(
        lambda: engine.chain_mvm(G, x, key=k_mvm, activation="relu"),
        iters=iters)
    us_solo = time_call(solo_forward, iters=iters)
    y_group = engine.chain_mvm(G, x, key=k_mvm, activation="relu")
    y_solo = solo_forward()
    return _row("chain", arch, d, L, us_group, us_solo,
                float(rel_l2(y_group, y_solo)))


def _bench_experts(arch: str, d: int, L: int, cfg: CrossbarConfig,
                   iters: int) -> Dict:
    """MoE pattern: L parallel expert kernels, one broadcast input."""
    key = jax.random.fold_in(jax.random.PRNGKey(17), d * 1000 + L)
    stack = jax.random.normal(key, (L, d, d), jnp.float32) / float(d)
    x = jax.random.normal(jax.random.fold_in(key, 1), (d,))
    k_mvm = jax.random.fold_in(key, 2)

    engine = AnalogEngine(cfg)
    G = engine.program_group(stack, key)
    handles = _solo_handles(engine, stack, key)

    def solo_experts():
        return jnp.stack([
            engine.mvm(A, x, key=jax.random.fold_in(k_mvm, g))
            for g, A in enumerate(handles)])

    us_group = time_call(lambda: engine.group_mvm(G, x, key=k_mvm),
                         iters=iters)
    us_solo = time_call(solo_experts, iters=iters)
    y_group = engine.group_mvm(G, x, key=k_mvm)
    y_solo = solo_experts()
    return _row("experts", arch, d, L, us_group, us_solo,
                float(rel_l2(y_group, y_solo)))


def _row(mode: str, arch: str, d: int, L: int, us_group: float,
         us_solo: float, parity: float) -> Dict:
    return {
        "name": f"model_dispatch/{mode}/{arch}/L{L}",
        "us_per_call": round(us_group, 1),
        "layers": L,
        "width": d,
        "us_group": round(us_group, 1),
        "us_solo": round(us_solo, 1),
        "speedup": round(us_solo / max(us_group, 1e-9), 2),
        "dispatches_group": 1,
        "dispatches_solo": L,
        "dispatch_reduction": L,
        "rel_l2_group_vs_solo": parity,
    }


def run(quick: bool = True, iters: int = 3) -> List[Dict]:
    cfg = CrossbarConfig(device=get_device("taox-hfox"), geom=GEOM,
                         k_iters=5, ec=True)
    layers = LAYERS_SMOKE if quick else LAYERS_FULL
    archs = ARCHS_SMOKE if quick else ARCHS_FULL
    rows: List[Dict] = []
    for arch, d in archs.items():
        for L in layers:
            rows.append(_bench_chain(arch, d, L, cfg, iters))
            rows.append(_bench_experts(arch, d, L, cfg, iters))
    _write_json(rows, quick)
    return rows


def _out_path(quick: bool) -> str:
    """Full sweeps refresh the checked-in baseline at the repo root; smoke
    runs (CI, ``benchmarks.run`` default) write to the temp dir."""
    if quick:
        return os.path.join(tempfile.gettempdir(),
                            "BENCH_model_dispatch.smoke.json")
    return OUT_JSON


def _write_json(rows: List[Dict], quick: bool) -> str:
    payload = {
        "bench": "model_dispatch",
        "mode": "smoke" if quick else "full",
        "metadata": run_metadata(),
        "geom": {"cap": CAP, "tiles": [1, 1]},
        "rows": rows,
    }
    out = _out_path(quick)
    with open(out, "w") as f:
        json.dump(payload, f, indent=2)
        f.write("\n")
    return out


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--smoke", action="store_true",
                    help="small sweep / single timing iter (CI fast job); "
                         "writes to the temp dir, leaving the checked-in "
                         "full-sweep JSON untouched")
    args = ap.parse_args()
    rows = run(quick=args.smoke, iters=1 if args.smoke else 3)
    for r in rows:
        print(f"{r['name']}: group {r['us_group']:.0f}us vs solo "
              f"{r['us_solo']:.0f}us ({r['speedup']:.1f}x wall, "
              f"{r['dispatch_reduction']}x dispatches), "
              f"parity {r['rel_l2_group_vs_solo']:.2e}")
    print(f"wrote {_out_path(args.smoke)}")
    # Acceptance contract: grouped execution cuts dispatches >= 5x once a
    # group holds >= 8 layers, and grouped-vs-solo parity stays <= 1e-5.
    deep = [r for r in rows if r["layers"] >= 8]
    assert deep, "sweep must include a >=8-layer group"
    assert all(r["dispatches_solo"] / r["dispatches_group"] >= 5
               for r in deep), deep
    assert all(r["rel_l2_group_vs_solo"] <= 1e-5 for r in rows), rows


if __name__ == "__main__":
    main()
