"""Paper Table 1: device performance for MVM with and without the two-tier
error correction, on M1 (bcsstk02, kappa=4325) and M2 (Iperturb, kappa~1.2).

EpiRAM (no EC) is the high-precision benchmark; Ag-aSi / AlOx-HfO2 / TaOx-HfOx
run both without and with EC.  All devices use the multi-iteration
adjustableWriteandVerify scheme (k=5, the paper's observed-sufficient count).
Validation targets (DESIGN.md section 7 / paper claims):

  * EC cuts the noisy devices' relative error by >~90% at converged k,
  * TaOx-HfOx + EC reaches EpiRAM-class accuracy,
  * while spending ~3 orders of magnitude less write energy and
    ~2 orders less write latency.
"""
from __future__ import annotations

import time
from typing import Dict, List

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (CrossbarConfig, MCAGeometry, get_device,
                        rel_l2, rel_linf)
from repro.core.matrices import make_iperturb, paper_matrix
from repro.engine import AnalogEngine

DEVICES = ["epiram", "ag-si", "alox-hfo2", "taox-hfox"]
GEOM_66 = MCAGeometry(tile_rows=1, tile_cols=1, cell_rows=66, cell_cols=66)


def one_cell(a, x, b, device_name, ec, k_iters, reps, key) -> Dict:
    key = jax.random.fold_in(key, hash(device_name) % (2 ** 30))
    dev = get_device(device_name)
    cfg = CrossbarConfig(device=dev, geom=GEOM_66, k_iters=k_iters, ec=ec)
    engine = AnalogEngine(cfg)
    A = engine.program(a, key)                    # one-time conductance write
    e2s, eis = [], []
    t0 = time.perf_counter()
    for r in range(reps):
        # Execute-many: every rep reuses the programmed image (zero re-encode);
        # us_per_call therefore times the serving hot path.
        y = engine.mvm(A, x, key=jax.random.fold_in(key, r))
        e2s.append(float(rel_l2(y, b)))
        eis.append(float(rel_linf(y, b)))
    us = (time.perf_counter() - t0) / reps * 1e6
    per_call = A.input_write_stats(batch=1)
    # E_w/L_w keep the legacy one-shot accounting (program + one input write)
    # so the paper's Table-1 ratios are directly comparable.
    return {
        "eps_l2": float(np.mean(e2s)), "eps_linf": float(np.mean(eis)),
        "E_w": float(A.write_stats.energy_j) + float(per_call.energy_j),
        "L_w": float(A.write_stats.latency_s) + float(per_call.latency_s),
        "E_program": float(A.write_stats.energy_j),
        "E_per_mvm": float(per_call.energy_j),
        "us_per_call": us,
    }


def run(quick: bool = True) -> List[Dict]:
    reps = 10 if quick else 100
    k = 5
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(jax.random.PRNGKey(42), (66,))
    rows: List[Dict] = []
    for mat_name, mat in [("M1_bcsstk02", paper_matrix("bcsstk02")),
                          ("M2_iperturb", make_iperturb(66))]:
        a = jnp.asarray(mat, jnp.float32)
        b = a @ x
        for dev in DEVICES:
            for ec in ([False] if dev == "epiram" else [False, True]):
                cell = one_cell(a, x, b, dev, ec, k, reps, key)
                rows.append({
                    "name": f"table1/{mat_name}/{dev}/{'ec' if ec else 'raw'}",
                    **cell,
                })
    # headline derived metrics
    get = lambda n: next(r for r in rows if r["name"] == n)
    for m in ("M1_bcsstk02", "M2_iperturb"):
        epi = get(f"table1/{m}/epiram/raw")
        tao_raw = get(f"table1/{m}/taox-hfox/raw")
        tao_ec = get(f"table1/{m}/taox-hfox/ec")
        rows.append({
            "name": f"table1/{m}/claims",
            "ec_error_reduction_pct":
                round(100 * (1 - tao_ec["eps_l2"] / tao_raw["eps_l2"]), 1),
            "taox_ec_vs_epiram_err": round(tao_ec["eps_l2"] / epi["eps_l2"], 3),
            "energy_orders_saved":
                round(np.log10(epi["E_w"] / tao_ec["E_w"]), 2),
            "latency_orders_saved":
                round(np.log10(epi["L_w"] / tao_ec["L_w"]), 2),
        })
    return rows


if __name__ == "__main__":
    from .common import emit
    emit(run())
