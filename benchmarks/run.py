"""Benchmark entry point: one module per paper table/figure (+ the LM-step
framework bench).  Prints ``name,us_per_call,derived`` CSV.

    PYTHONPATH=src python -m benchmarks.run            # quick (CI) mode
    PYTHONPATH=src python -m benchmarks.run --full     # full paper protocol
    PYTHONPATH=src python -m benchmarks.run --only table1
"""
from __future__ import annotations

import argparse
import sys
import time

from .common import emit


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="paper-scale replication counts / sizes")
    ap.add_argument("--only", default=None,
                    help="substring filter on benchmark module name")
    args = ap.parse_args()
    quick = not args.full

    from . import (lm_step, lstsq_convergence, model_dispatch,
                   pdhg_convergence, reliability, serving, solver_convergence,
                   streamed_scaling, strong_scaling, table1_ec, weak_scaling,
                   writeverify_sweep)
    modules = [
        ("table1_ec", table1_ec),
        ("writeverify_sweep", writeverify_sweep),
        ("solver_convergence", solver_convergence),
        ("pdhg_convergence", pdhg_convergence),
        ("lstsq_convergence", lstsq_convergence),
        ("weak_scaling", weak_scaling),
        ("strong_scaling", strong_scaling),
        ("streamed_scaling", streamed_scaling),
        ("model_dispatch", model_dispatch),
        ("lm_step", lm_step),
        ("serving", serving),
        ("reliability", reliability),
    ]
    print("name,us_per_call,derived")
    for name, mod in modules:
        if args.only and args.only not in name:
            continue
        t0 = time.perf_counter()
        rows = mod.run(quick=quick)
        emit(rows)
        print(f"# {name}: {time.perf_counter() - t0:.1f}s", file=sys.stderr)


if __name__ == "__main__":
    main()
