"""PDHG linear-programming convergence: device x EC x placement sweep.

The distributed-PDHG companion paper's workload on our engine: random
feasible LPs with a KNOWN optimal objective
(:func:`repro.solvers.random_feasible_lp`) solved by
:func:`repro.solvers.pdhg` against one programmed image -- every iteration is
one corrected forward MVM plus one corrected TRANSPOSED MVM (``rmatvec``),
both billed to the ledger.  Reported per row:

  * ``iters``     -- PDHG iterations to the KKT tolerance;
  * ``obj_gap``   -- |objective - known optimum| / (1 + |optimum|);
  * ``oracle_gap``-- |objective - digital-PDHG objective| / (1 + |.|), the
                     acceptance metric (<= 1e-3 for the precision device);
  * ``E_write_J`` / ``E_iters_J`` -- one-time write vs per-iteration energy
                     (forward + transposed input writes).

Results land in ``BENCH_pdhg_convergence.json`` (full runs refresh the
checked-in baseline at the repo root; smoke/quick runs write to the temp
dir), with the initialized device count + ``XLA_FLAGS`` recorded in the
metadata block.

    PYTHONPATH=src python -m benchmarks.pdhg_convergence            # quick
    PYTHONPATH=src python -m benchmarks.pdhg_convergence --smoke    # CI
    PYTHONPATH=src python -m benchmarks.pdhg_convergence --full
"""
from __future__ import annotations

import argparse
import json
import os
import tempfile
from typing import Dict, List

import jax
import jax.numpy as jnp

from repro import solvers
from repro.core import CrossbarConfig, MCAGeometry, get_device, rel_l2
from repro.engine import AnalogEngine

from .common import run_metadata

OUT_JSON = os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "BENCH_pdhg_convergence.json")

# (m, n, cell, tol, maxiter)
CASE_SMOKE = (64, 96, 32, 1e-3, 4000)
CASE_QUICK = (128, 192, 64, 3e-4, 10000)
CASE_FULL = (256, 512, 64, 2e-4, 30000)

DEVICES_QUICK = ["epiram", "taox-hfox"]
DEVICES_FULL = ["epiram", "ag-si", "alox-hfo2", "taox-hfox"]


def _solve_case(device: str, ec: bool, a, b, c, obj_star, digital_obj,
                tol, maxiter, cell) -> Dict:
    geom = MCAGeometry(tile_rows=1, tile_cols=1,
                       cell_rows=cell, cell_cols=cell)
    cfg = CrossbarConfig(device=get_device(device), geom=geom, k_iters=5,
                         ec=ec)
    engine = AnalogEngine(cfg)
    key = jax.random.PRNGKey(3)
    A = engine.program(a, key)
    res = solvers.pdhg(A, b, c, tol=tol, maxiter=maxiter, key=key)
    obj = float(c @ res.x)
    led = res.ledger
    return {
        "name": f"pdhg/{device}/{'ec' if ec else 'raw'}",
        "iters": res.iterations,
        "converged": bool(res.converged),
        "kkt": res.final_residual,
        "obj_gap": abs(obj - obj_star) / (1 + abs(obj_star)),
        "oracle_gap": abs(obj - digital_obj) / (1 + abs(digital_obj)),
        "primal_feas": float(rel_l2(a @ res.x, b)),
        "mvms": led.mvms,
        "mvms_t": led.mvms_t,
        "E_write_J": led.write_energy_j,
        "E_iters_J": led.iteration_energy_j,
    }


def run(quick: bool = True, smoke: bool = False) -> List[Dict]:
    m, n, cell, tol, maxiter = CASE_SMOKE if smoke else \
        (CASE_QUICK if quick else CASE_FULL)
    devices = DEVICES_QUICK if (quick or smoke) else DEVICES_FULL
    key = jax.random.PRNGKey(17)
    a, b, c, x_star, _ = solvers.random_feasible_lp(key, m, n)
    obj_star = float(c @ x_star)
    digital = solvers.pdhg(a, b, c, tol=tol, maxiter=maxiter)
    digital_obj = float(c @ digital.x)
    rows = [{
        "name": f"pdhg/digital/m{m}n{n}",
        "iters": digital.iterations,
        "converged": bool(digital.converged),
        "kkt": digital.final_residual,
        "obj_gap": abs(digital_obj - obj_star) / (1 + abs(obj_star)),
        "oracle_gap": 0.0,
        "primal_feas": float(rel_l2(a @ digital.x, b)),
        "mvms": digital.ledger.mvms,
        "mvms_t": digital.ledger.mvms_t,
        "E_write_J": 0.0,
        "E_iters_J": 0.0,
    }]
    for device in devices:
        rows.append(_solve_case(device, True, a, b, c, obj_star, digital_obj,
                                tol, maxiter, cell))
    # EC off on the precision device: shows what tier-1+2 correction buys
    rows.append(_solve_case(devices[0], False, a, b, c, obj_star,
                            digital_obj, tol, maxiter, cell))
    _write_json(rows, quick or smoke, "smoke" if smoke else
                ("quick" if quick else "full"))
    return rows


def _out_path(quick: bool) -> str:
    if quick:
        return os.path.join(tempfile.gettempdir(),
                            "BENCH_pdhg_convergence.smoke.json")
    return OUT_JSON


def _write_json(rows: List[Dict], quick: bool, mode: str) -> str:
    payload = {
        "bench": "pdhg_convergence",
        "mode": mode,
        "metadata": run_metadata(),
        "rows": rows,
    }
    out = _out_path(quick)
    with open(out, "w") as f:
        json.dump(payload, f, indent=2)
        f.write("\n")
    return out


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--smoke", action="store_true",
                    help="tiny LP / loose tol (CI fast job); writes to the "
                         "temp dir")
    ap.add_argument("--full", action="store_true",
                    help="paper-scale LP + all four devices; refreshes the "
                         "checked-in JSON")
    args = ap.parse_args()
    rows = run(quick=not args.full, smoke=args.smoke)
    for r in rows:
        print(f"{r['name']}: {r['iters']} iters, kkt {r['kkt']:.1e}, "
              f"obj_gap {r['obj_gap']:.1e}, oracle_gap "
              f"{r['oracle_gap']:.1e}, E_iters {r['E_iters_J']:.2e} J")
    print(f"wrote {_out_path(not args.full)}")
    # CI contract: the precision device with EC matches the digital oracle.
    # Smoke mode solves to a loose 1e-3 KKT tol, so its oracle gap sits just
    # under 1e-3 by construction -- gate it at 2e-3 to leave numeric headroom
    # (jax/BLAS upgrades shift the trajectory slightly); quick/full solve
    # tighter and keep the 1e-3 acceptance bound.
    ec_row = next(r for r in rows if r["name"].startswith("pdhg/epiram/ec"))
    assert ec_row["oracle_gap"] <= (2e-3 if args.smoke else 1e-3), ec_row


if __name__ == "__main__":
    main()
