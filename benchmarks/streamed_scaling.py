"""Streamed single-dispatch scaling: scan-fused pipeline vs the host loop.

The paper's scalability showcase (matrices beyond 65,000^2) executes MVMs
block-by-block against a streamed producer.  Pre-scan, that was a Python
double loop -- O(mb * nb) host->device dispatches per MVM, re-paid every
solver iteration -- so the framework was dispatch-bound long before it was
compute-bound.  This benchmark sweeps the capacity-block count and reports,
for the same producer and keys:

  * ``us_scan``  -- wall-clock of the scan-fused pipeline (ONE dispatch/MVM);
  * ``us_loop``  -- wall-clock of the compat host loop (mb * nb dispatches),
                    forced via an explicit ``traceable = False`` marker;
  * producer invocations per *warm* MVM (0 scanned vs mb * nb looped) -- the
    host-work proxy for the dispatch count;
  * ``rel_l2``   -- parity between the two paths (same keys => same draws).

Results land in ``BENCH_streamed_scaling.json`` at the repo root (checked in,
so later PRs can compare against this trajectory).

    PYTHONPATH=src python -m benchmarks.streamed_scaling            # full sweep
    PYTHONPATH=src python -m benchmarks.streamed_scaling --smoke    # CI fast job
"""
from __future__ import annotations

import argparse
import json
import os
import tempfile
from typing import Dict, List

import jax

from repro.core import CrossbarConfig, MCAGeometry, get_device, rel_l2
from repro.core.matrices import ImplicitBandedMatrix
from repro.engine import AnalogEngine

from .common import run_metadata, time_call

CAP = 32                                   # capacity block edge (1x1 tile MCA)
GEOM = MCAGeometry(tile_rows=1, tile_cols=1, cell_rows=CAP, cell_cols=CAP)
GRIDS_FULL = [2, 4, 8, 16]                 # nb x nb capacity blocks
GRIDS_SMOKE = [2, 4]
OUT_JSON = os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "BENCH_streamed_scaling.json")


def _counting(fn):
    calls = {"n": 0}

    def wrapped(i, j):
        calls["n"] += 1
        return fn(i, j)

    return wrapped, calls


def _bench_grid(nb: int, cfg: CrossbarConfig, iters: int) -> Dict:
    n = nb * CAP
    key = jax.random.fold_in(jax.random.PRNGKey(42), n)
    imp = ImplicitBandedMatrix(n=n, cap_m=CAP, cap_n=CAP, seed=nb)
    x = jax.random.normal(jax.random.fold_in(key, 1), (n,))

    # Scan-fused pipeline: the producer is traceable, so program and every
    # MVM are single dispatches.
    scan_fn, scan_calls = _counting(imp.block)
    eng_scan = AnalogEngine(cfg, execution="streamed")
    A_scan = eng_scan.program(scan_fn, key, shape=(n, n))
    assert A_scan.block_traceable

    # Pre-PR regime: identical producer/keys, host loop forced per block.
    loop_fn, loop_calls = _counting(imp.block)
    loop_fn.traceable = False
    eng_loop = AnalogEngine(cfg, execution="streamed")
    A_loop = eng_loop.program(loop_fn, key, shape=(n, n))
    assert not A_loop.block_traceable

    k_mvm = jax.random.fold_in(key, 2)
    us_scan = time_call(lambda: eng_scan.mvm(A_scan, x, key=k_mvm),
                        iters=iters)
    us_loop = time_call(lambda: eng_loop.mvm(A_loop, x, key=k_mvm),
                        iters=iters)

    # Host-work per warm MVM (the dispatch-count proxy): one measured call.
    c0 = scan_calls["n"]
    y_scan = eng_scan.mvm(A_scan, x, key=k_mvm)
    scan_per_mvm = scan_calls["n"] - c0
    c0 = loop_calls["n"]
    y_loop = eng_loop.mvm(A_loop, x, key=k_mvm)
    loop_per_mvm = loop_calls["n"] - c0

    return {
        "name": f"streamed_scaling/grid{nb}x{nb}/n{n}",
        "us_per_call": round(us_scan, 1),
        "n": n,
        "blocks": nb * nb,
        "us_scan": round(us_scan, 1),
        "us_loop": round(us_loop, 1),
        "speedup": round(us_loop / max(us_scan, 1e-9), 2),
        "producer_calls_per_mvm_scan": scan_per_mvm,
        "producer_calls_per_mvm_loop": loop_per_mvm,
        "dispatches_per_mvm_scan": 1,
        "dispatches_per_mvm_loop": nb * nb,
        "rel_l2_scan_vs_loop": float(rel_l2(y_scan, y_loop)),
    }


def run(quick: bool = True, iters: int = 3) -> List[Dict]:
    cfg = CrossbarConfig(device=get_device("taox-hfox"), geom=GEOM,
                         k_iters=5, ec=True)
    grids = GRIDS_SMOKE if quick else GRIDS_FULL
    rows = [_bench_grid(nb, cfg, iters) for nb in grids]
    _write_json(rows, quick)
    return rows


def _out_path(quick: bool) -> str:
    """Full sweeps refresh the checked-in trajectory file at the repo root;
    quick/smoke runs (CI, ``benchmarks.run`` default) write to the temp dir
    so they never clobber the committed full-sweep baseline."""
    if quick:
        return os.path.join(tempfile.gettempdir(),
                            "BENCH_streamed_scaling.smoke.json")
    return OUT_JSON


def _write_json(rows: List[Dict], quick: bool) -> str:
    payload = {
        "bench": "streamed_scaling",
        "mode": "smoke" if quick else "full",
        "metadata": run_metadata(),
        "geom": {"cap": CAP, "tiles": [1, 1]},
        "rows": rows,
    }
    out = _out_path(quick)
    with open(out, "w") as f:
        json.dump(payload, f, indent=2)
        f.write("\n")
    return out


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--smoke", action="store_true",
                    help="small grids / single timing iter (CI fast job); "
                         "writes to the temp dir, leaving the checked-in "
                         "full-sweep JSON untouched")
    args = ap.parse_args()
    rows = run(quick=args.smoke, iters=1 if args.smoke else 3)
    for r in rows:
        print(f"{r['name']}: scan {r['us_scan']:.0f}us vs loop "
              f"{r['us_loop']:.0f}us ({r['speedup']:.1f}x), "
              f"parity {r['rel_l2_scan_vs_loop']:.2e}")
    print(f"wrote {_out_path(args.smoke)}")


if __name__ == "__main__":
    main()
