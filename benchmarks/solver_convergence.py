"""Solver convergence/energy sweep: device x EC on/off x solver.

For each RRAM device model, with and without the two-tier error correction,
runs the ``repro.solvers`` methods against one programmed image of an SPD
system and reports

  * iterations-to-tolerance (NaN-free count actually executed),
  * the final relative residual and true solution error,
  * joules-per-solve, split into the one-time programming energy and the
    accumulated per-MVM input-write energy (the amortization the paper's
    program-once model buys).

Quick mode (CI) solves a 128-dim system with the matvec-only trio
(richardson / cg / bicgstab); full mode grows the system, adds gmres +
mixed-precision refinement, and sweeps all four devices.

    PYTHONPATH=src python -m benchmarks.run --only solver
    PYTHONPATH=src python -m benchmarks.solver_convergence --full
"""
from __future__ import annotations

import time
from typing import Dict, List

import jax
import jax.numpy as jnp

from repro import solvers
from repro.core import CrossbarConfig, MCAGeometry, get_device, rel_l2
from repro.engine import AnalogEngine

QUICK_DEVICES = ["epiram", "taox-hfox"]
FULL_DEVICES = ["epiram", "ag-si", "alox-hfo2", "taox-hfox"]


def _spd_system(n: int, key: jax.Array):
    r = jax.random.normal(key, (n, n), jnp.float32) / n
    a = r + r.T + 2.0 * jnp.eye(n, dtype=jnp.float32)
    x_true = jax.random.normal(jax.random.fold_in(key, 1), (n,), jnp.float32)
    return a, x_true, a @ x_true


def _solver_menu(quick: bool):
    menu = [
        ("richardson", lambda A, b, tol, it:
            solvers.richardson(A, b, tol=tol, maxiter=it)),
        ("cg", lambda A, b, tol, it: solvers.cg(A, b, tol=tol, maxiter=it)),
        ("bicgstab", lambda A, b, tol, it:
            solvers.bicgstab(A, b, tol=tol, maxiter=it)),
    ]
    if not quick:
        menu += [
            ("gmres", lambda A, b, tol, it:
                solvers.gmres(A, b, tol=tol, maxiter=it, restart=10)),
            ("refine_cg", lambda A, b, tol, it:
                solvers.refine(A, b, tol=tol, maxiter=it, inner_iters=6)),
        ]
    return menu


def run(quick: bool = True) -> List[Dict]:
    n = 128 if quick else 512
    cell = 32 if quick else 64
    tol = 1e-3
    maxiter = 40 if quick else 80
    key = jax.random.PRNGKey(0)
    a, x_true, b = _spd_system(n, key)
    geom = MCAGeometry(tile_rows=2, tile_cols=2, cell_rows=cell,
                       cell_cols=cell)
    rows: List[Dict] = []
    for dev in (QUICK_DEVICES if quick else FULL_DEVICES):
        for ec in (False, True):
            cfg = CrossbarConfig(device=get_device(dev), geom=geom,
                                 k_iters=5, ec=ec)
            engine = AnalogEngine(cfg)
            A = engine.program(a, jax.random.fold_in(key, 7))
            for sname, solve in _solver_menu(quick):
                t0 = time.perf_counter()
                res = solve(A, b, tol, maxiter)
                us = (time.perf_counter() - t0) * 1e6
                led = res.ledger
                rows.append({
                    "name": f"solver/{dev}/{'ec' if ec else 'raw'}/{sname}",
                    "us_per_call": round(us, 1),
                    "iters": res.iterations,
                    "converged": res.converged,
                    "resid": f"{res.final_residual:.3e}",
                    "x_err": f"{float(rel_l2(res.x, x_true)):.3e}",
                    "mvms": led.mvms,
                    "E_write_J": f"{led.write_energy_j:.3e}",
                    "E_iters_J": f"{led.iteration_energy_j:.3e}",
                    "E_total_J": f"{led.total_energy_j:.3e}",
                })
    return rows


if __name__ == "__main__":
    import argparse

    from .common import emit

    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    emit(run(quick=not args.full))
