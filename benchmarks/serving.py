"""Serving under synthetic traffic: SLO + energy rows for the analog stack.

Two scenario families over the :mod:`repro.serving` simulator:

  * **service quality** -- one seeded mixed-tenant trace (two zoo models:
    rwkv6-1.6b + qwen3-1.7b, Zipf-skewed tenants, Poisson arrivals) served by
    the digital fp32 baseline and by the analog backend on >= 2 device
    configs.  Rows report tokens/sec, p50/p99 latency, and joules-per-token;
    analog rows run the REAL Server numerics (jitted prefill + ONE scan-fused
    decode dispatch per batch) while the analytic write-cost model drives the
    simulated clock.
  * **eviction policy** -- the skewed-tenant cache-pressure trace (one hot
    expensive image + rotating cold cheap tenants, capacity fits the hot
    image plus one small) replayed under LRU and under the write-cost-aware
    policy.  The acceptance contract asserts the write-cost-aware policy pays
    STRICTLY less total write energy than LRU on the same trace.

Results land in ``BENCH_serving.json`` (full runs refresh the checked-in
baseline at the repo root; smoke/quick runs write to the temp dir), stamped
with ``run_metadata()``.

    PYTHONPATH=src python -m benchmarks.serving            # quick
    PYTHONPATH=src python -m benchmarks.serving --smoke    # CI
    PYTHONPATH=src python -m benchmarks.serving --full
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import os
import tempfile
from typing import Dict, List

from repro.configs.base import RRAMBackendConfig
from repro.serving import (BatchingConfig, ServingConfig, TenantSpec,
                           TrafficConfig, simulate)

from .common import run_metadata

OUT_JSON = os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "BENCH_serving.json")

DEVICES_QUICK = ["epiram"]
DEVICES_FULL = ["epiram", "taox-hfox"]

# capacity fits the hot rwkv6 image (~672 KiB) + one zamba2 image (~240 KiB)
SKEW_CAPACITY = 1_100_000

_BATCHING = BatchingConfig(max_batch=4, prompt_buckets=(8, 16),
                           decode_buckets=(4, 8), batch_buckets=(1, 2, 4))


def _mixed_cfg(n_requests: int, rram, seed: int = 0) -> ServingConfig:
    """The service-quality trace: two zoo models, four tenants, Zipf skew."""
    tenants = (TenantSpec("acme", "rwkv6-1.6b"),
               TenantSpec("globex", "qwen3-1.7b"),
               TenantSpec("initech", "rwkv6-1.6b"),
               TenantSpec("umbrella", "qwen3-1.7b"))
    traffic = TrafficConfig(n_requests=n_requests, rate_rps=6.0, zipf_s=1.0,
                            prompt_lens=(6, 12), prompt_mix=(0.6, 0.4),
                            decode_lens=(4, 8), decode_mix=(0.6, 0.4),
                            seed=seed)
    return ServingConfig(tenants=tenants, traffic=traffic, batching=_BATCHING,
                         rram=rram, cache_capacity_bytes=1 << 23,
                         policy="write_cost", seed=seed, max_len=32)


def _skew_cfg(n_requests: int, policy: str, seed: int = 0) -> ServingConfig:
    """The cache-pressure trace: hot expensive tenant + cold cheap tenants."""
    tenants = (TenantSpec("hot", "rwkv6-1.6b"),
               TenantSpec("cold-a", "zamba2-1.2b"),
               TenantSpec("cold-b", "zamba2-1.2b"),
               TenantSpec("cold-c", "zamba2-1.2b"),
               TenantSpec("cold-d", "zamba2-1.2b"))
    traffic = TrafficConfig(n_requests=n_requests, rate_rps=2.0, zipf_s=1.0,
                            prompt_lens=(6, 12), prompt_mix=(0.6, 0.4),
                            decode_lens=(4, 8), decode_mix=(0.6, 0.4),
                            seed=seed)
    return ServingConfig(tenants=tenants, traffic=traffic,
                         batching=dataclasses.replace(_BATCHING, max_batch=2),
                         rram=RRAMBackendConfig(enabled=True),
                         cache_capacity_bytes=SKEW_CAPACITY, policy=policy,
                         seed=seed, max_len=32, run_model=False)


def _service_row(name: str, summary: Dict) -> Dict:
    row = {
        "name": name,
        "tokens_per_s": summary["tokens_per_s"],
        "p50_latency_s": summary["p50_latency_s"],
        "p99_latency_s": summary["p99_latency_s"],
        "joules_per_token": summary["joules_per_token"],
        "exec_energy_j": summary["exec_energy_j"],
        "write_energy_j": summary["write_energy_j"],
        "padding_overhead": summary["padding_overhead"],
        "n_requests": summary["n_requests"],
        "useful_tokens": summary["useful_tokens"],
        "dispatches_per_batch": summary["dispatches_per_batch"],
        "exec_dispatches": summary["exec_dispatches"],
        "program_dispatches": summary["program_dispatches"],
    }
    if "cache" in summary:
        row["cache_hits"] = summary["cache"]["hits"]
        row["cache_reprograms"] = summary["cache"]["reprograms"]
    return row


def run(quick: bool = True, smoke: bool = False) -> List[Dict]:
    n = 8 if smoke else (24 if quick else 64)
    n_skew = 24 if smoke else (36 if quick else 96)
    devices = DEVICES_QUICK if (quick or smoke) else DEVICES_FULL

    rows = [_service_row("serving/digital",
                         simulate(_mixed_cfg(n, None)).summary)]
    for device in devices:
        rram = RRAMBackendConfig(enabled=True, device=device)
        rows.append(_service_row(f"serving/analog/{device}",
                                 simulate(_mixed_cfg(n, rram)).summary))

    for policy in ("lru", "write_cost"):
        res = simulate(_skew_cfg(n_skew, policy))
        cs = res.cache_stats
        rows.append({
            "name": f"serving/evict/{policy}",
            "write_energy_j": cs["write_energy_j"],
            "reprograms": cs["reprograms"],
            "evictions": cs["evictions"],
            "hits": cs["hits"],
            "misses": cs["misses"],
            "joules_per_token": res.summary["joules_per_token"],
            "p99_latency_s": res.summary["p99_latency_s"],
        })

    _write_json(rows, quick or smoke,
                "smoke" if smoke else ("quick" if quick else "full"))
    return rows


def _out_path(quick: bool) -> str:
    if quick:
        return os.path.join(tempfile.gettempdir(),
                            "BENCH_serving.smoke.json")
    return OUT_JSON


def _write_json(rows: List[Dict], quick: bool, mode: str) -> str:
    payload = {
        "bench": "serving",
        "mode": mode,
        "metadata": run_metadata(),
        "rows": rows,
    }
    out = _out_path(quick)
    with open(out, "w") as f:
        json.dump(payload, f, indent=2)
        f.write("\n")
    return out


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--smoke", action="store_true",
                    help="tiny trace, one device (CI fast job); writes to "
                         "the temp dir")
    ap.add_argument("--full", action="store_true",
                    help="full trace + both devices; refreshes the "
                         "checked-in JSON")
    args = ap.parse_args()
    rows = run(quick=not args.full, smoke=args.smoke)
    for r in rows:
        extra = (f"write {r['write_energy_j']:.2e} J"
                 if r["name"].startswith("serving/evict")
                 else f"{r['tokens_per_s']:.2f} tok/s, "
                      f"p99 {r['p99_latency_s']:.2f} s")
        print(f"{r['name']}: j/tok {r['joules_per_token']:.3e}, {extra}")
    print(f"wrote {_out_path(not args.full)}")
    # CI contract: write-cost-aware eviction strictly beats LRU on total
    # write energy for the same skewed trace.
    lru = next(r for r in rows if r["name"] == "serving/evict/lru")
    wc = next(r for r in rows if r["name"] == "serving/evict/write_cost")
    assert wc["write_energy_j"] < lru["write_energy_j"], (wc, lru)


if __name__ == "__main__":
    main()
