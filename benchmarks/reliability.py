"""Device-lifetime reliability: aging x refresh policy x fault-tolerant solves.

Three row families over :mod:`repro.reliability`:

  * **aging + refresh policy** -- per device model, one programmed SPD image
    is aged by the device's own read-disturb fault process (MVM count tuned
    so ~8 cells latch), then solved under three refresh policies: ``none``
    (solve the damaged image), ``tiles`` (probe, re-program only the tiles
    above threshold), ``full`` (re-program everything).  Rows report the
    DIGITAL solve residual ||b - A x|| / ||b|| (the recursive analog residual
    lies on a damaged operator) and the actual write-verify energy.
  * **fault-tolerant solves** -- :func:`repro.reliability.ft_cg` with a
    stuck-column fault injected mid-solve, in-process on a local handle and
    in a subprocess on a 2x4 device mesh (distributed dense execution, the
    host-side ``at_dense`` injection path).
  * **serving refresh scheduling** -- the :mod:`repro.serving` simulator with
    and without a :class:`~repro.serving.ReliabilityConfig`, trading refresh
    stalls/energy against the predicted residual images are served at.

Acceptance contracts asserted by ``main()``:

  A. the unrefreshed aged solve residual exceeds tolerance, and
     tile-selective refresh restores the solve to within 2x the fresh-image
     residual at STRICTLY less write energy than full reprogramming;
  B. a mid-solve injected stuck-at fault in distributed CG is detected and
     recovered through CheckpointManager to ``converged=True`` on a 2x4 mesh.

Results land in ``BENCH_reliability.json`` (full runs refresh the checked-in
baseline; smoke/quick runs write to the temp dir), stamped with
``run_metadata()``.

    PYTHONPATH=src python -m benchmarks.reliability            # quick
    PYTHONPATH=src python -m benchmarks.reliability --smoke    # CI
    PYTHONPATH=src python -m benchmarks.reliability --full
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import tempfile
import textwrap
from typing import Dict, List

import numpy as np

import jax
import jax.numpy as jnp

from repro.core import CrossbarConfig, MCAGeometry, get_device
from repro.engine import AnalogEngine
from repro.reliability import (RefreshPolicy, attach_age, ft_cg,
                               predicted_residual, probe_tile_scores,
                               refresh_tiles)
from repro.solvers import cg

from .common import run_metadata

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
OUT_JSON = os.path.join(REPO, "BENCH_reliability.json")

DEVICES_SMOKE = ["epiram"]
DEVICES_QUICK = ["epiram", "taox-hfox"]
DEVICES_FULL = ["epiram", "ag-si", "alox-hfo2", "taox-hfox"]

#: aged-solve digital residual above this counts as "image needs refresh"
AGED_TOL = 1e-2
#: expected number of latched cells the aging scenario targets
TARGET_FAULTS = 8.0


def _spd_system(n: int, key: jax.Array):
    r = jax.random.normal(key, (n, n), jnp.float32) / n
    a = r + r.T + 2.0 * jnp.eye(n, dtype=jnp.float32)
    x_true = jax.random.normal(jax.random.fold_in(key, 1), (n,), jnp.float32)
    return a, x_true, a @ x_true


def _aging_mvms(device, n: int) -> int:
    """MVM count at which ~TARGET_FAULTS cells of an n x n image latch."""
    return max(1, int(TARGET_FAULTS / (device.fault_rate * n * n)))


def _aging_rows(device_name: str, n: int) -> List[Dict]:
    """One device's aging scenario under the none/tiles/full refresh menu."""
    key = jax.random.PRNGKey(0)
    a, _x_true, b = _spd_system(n, key)
    bn = float(jnp.linalg.norm(b))
    dev = get_device(device_name)
    cfg = CrossbarConfig(device=dev, geom=MCAGeometry(2, 2, 32, 32),
                         k_iters=5, ec=True)
    mvms = _aging_mvms(dev, n)

    def fresh_aged_handle(salt: int):
        engine = AnalogEngine(cfg)
        A = engine.program(a, jax.random.fold_in(key, 7))
        attach_age(A)
        res = cg(A, b, tol=1e-6, maxiter=120,
                 key=jax.random.fold_in(key, salt))
        fresh_rel = float(jnp.linalg.norm(b - a @ res.x)) / bn
        A.age = A.age.advanced(mvms)
        return A, fresh_rel

    def digital_rel(A, salt: int) -> float:
        res = cg(A, b, tol=1e-6, maxiter=120,
                 key=jax.random.fold_in(key, salt))
        return float(jnp.linalg.norm(b - a @ res.x)) / bn

    rows: List[Dict] = []
    pred = predicted_residual(dev, k_iters=cfg.k_iters, seconds=0.0,
                              mvms=mvms, n=n)

    # none: solve the damaged image as-is
    A, fresh_rel = fresh_aged_handle(11)
    aged_rel = digital_rel(A, 12)
    rows.append({"name": f"reliability/age/{device_name}/none",
                 "fresh_rel": f"{fresh_rel:.3e}",
                 "solve_rel": f"{aged_rel:.3e}",
                 "predicted": f"{pred:.3e}", "aged_mvms": mvms,
                 "refresh_energy_j": 0.0, "tiles_refreshed": 0})

    # tiles: probe, re-program only the flagged tiles
    report = probe_tile_scores(A, key=jax.random.fold_in(key, 13))
    rr = refresh_tiles(A, report.scores, RefreshPolicy(threshold=0.01),
                       key=jax.random.fold_in(key, 14))
    tiles_rel = digital_rel(A, 15)
    rows.append({"name": f"reliability/age/{device_name}/tiles",
                 "fresh_rel": f"{fresh_rel:.3e}",
                 "solve_rel": f"{tiles_rel:.3e}",
                 "probe_worst": f"{report.worst:.3e}",
                 "refresh_energy_j": float(rr.write_stats.energy_j),
                 "full_rewrite_j": float(rr.full_rewrite_stats.energy_j),
                 "energy_saving": round(rr.energy_saving, 3),
                 "tiles_refreshed": len(rr.tiles),
                 "tiles_total": int(report.scores.size)})

    # full: re-program every tile (threshold below any score selects all)
    A2, _ = fresh_aged_handle(11)
    report2 = probe_tile_scores(A2, key=jax.random.fold_in(key, 13))
    rr2 = refresh_tiles(A2, report2.scores, RefreshPolicy(threshold=-1.0),
                        key=jax.random.fold_in(key, 14))
    full_rel = digital_rel(A2, 15)
    rows.append({"name": f"reliability/age/{device_name}/full",
                 "fresh_rel": f"{fresh_rel:.3e}",
                 "solve_rel": f"{full_rel:.3e}",
                 "refresh_energy_j": float(rr2.write_stats.energy_j),
                 "tiles_refreshed": len(rr2.tiles)})
    return rows


def _ft_local_row(n: int) -> Dict:
    """In-process fault-tolerant CG: stuck column injected at segment 1 on a
    local handle, repaired by the ``on_fault`` callback."""
    key = jax.random.PRNGKey(2)
    a, _x_true, b = _spd_system(n, key)
    cfg = CrossbarConfig(device=get_device("epiram"),
                         geom=MCAGeometry(2, 2, 32, 32), k_iters=5, ec=True)
    A = AnalogEngine(cfg).program(a, jax.random.fold_in(key, 7))

    state = {"saved": None}

    def inject(seg, h):
        if seg == 1 and state["saved"] is None:
            state["saved"] = h.at_blocks
            blocks = np.array(jax.device_get(h.at_blocks))
            # full physical column stuck at the G_on rail (both row blocks)
            blocks[:, 0, :, 3] = np.max(np.abs(blocks))
            h.at_blocks = jnp.asarray(blocks)
            h.release()

    def repair(event, h):
        h.at_blocks = state["saved"]
        h.release()

    res = ft_cg(A, b, tol=1e-4, maxiter=400, segment=25,
                key=jax.random.fold_in(key, 9), segment_hook=inject,
                on_fault=repair)
    return {"name": "reliability/ft/cg/local",
            "converged": bool(res.converged),
            "restores": int(res.restores),
            "segments": int(res.iterations),
            "final_rel": f"{res.final_residual:.3e}",
            "events": ";".join(e.kind for e in res.fault_events)}


_DISTRIBUTED_CHILD = textwrap.dedent("""
    import json
    import numpy as np
    import jax, jax.numpy as jnp
    from repro.launch.mesh import make_mesh
    from repro.core import CrossbarConfig, MCAGeometry, get_device
    from repro.engine import AnalogEngine
    from repro.reliability import ft_cg

    mesh = make_mesh((2, 4), ("data", "model"))
    n = {n}
    key = jax.random.PRNGKey(0)
    r = jax.random.normal(key, (n, n), jnp.float32) / n
    a = r + r.T + 2.0 * jnp.eye(n, dtype=jnp.float32)
    x_true = jax.random.normal(jax.random.fold_in(key, 1), (n,), jnp.float32)
    b = a @ x_true

    cfg = CrossbarConfig(device=get_device("epiram"),
                         geom=MCAGeometry(2, 2, 16, 16), k_iters=5, ec=True)
    engine = AnalogEngine(cfg, execution="distributed", mesh=mesh)
    A = engine.program(a, jax.random.fold_in(key, 7))

    state = {{"saved": None}}

    def inject(seg, h):
        if seg == 1 and state["saved"] is None:
            state["saved"] = h.at_dense
            dense = np.array(jax.device_get(h.at_dense))
            dense[:, 5] = np.max(np.abs(dense))    # column stuck at G_on rail
            h.at_dense = jax.device_put(jnp.asarray(dense),
                                        h.at_dense.sharding)

    def repair(event, h):
        h.at_dense = state["saved"]

    res = ft_cg(A, b, tol=1e-4, maxiter=400, segment=25,
                key=jax.random.fold_in(key, 9), segment_hook=inject,
                on_fault=repair)
    print(json.dumps({{
        "converged": bool(res.converged), "restores": int(res.restores),
        "segments": int(res.iterations),
        "final_rel": float(res.final_residual),
        "events": [e.kind for e in res.fault_events],
        "devices": jax.device_count()}}))
""")


def _ft_distributed_row(n: int) -> Dict:
    """Contract B in a subprocess: 8 virtual host devices, 2x4 mesh, a fault
    injected into the sharded dense image mid-solve."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    env.setdefault("JAX_PLATFORMS", "cpu")
    out = subprocess.run([sys.executable, "-c",
                          _DISTRIBUTED_CHILD.format(n=n)],
                         capture_output=True, text=True, env=env, timeout=900)
    assert out.returncode == 0, f"child failed:\n{out.stdout}\n{out.stderr}"
    res = json.loads(out.stdout.splitlines()[-1])
    return {"name": "reliability/ft/cg/distributed-2x4",
            "converged": bool(res["converged"]),
            "restores": int(res["restores"]),
            "segments": int(res["segments"]),
            "final_rel": f"{res['final_rel']:.3e}",
            "events": ";".join(res["events"]),
            "devices": int(res["devices"])}


def _serving_rows(n_requests: int) -> List[Dict]:
    """Refresh scheduling vs traffic: the simulator with and without the
    reliability controller, on the fast-drifting ag-si device."""
    from repro.configs.base import RRAMBackendConfig
    from repro.serving import (ReliabilityConfig, ServingConfig, TenantSpec,
                               TrafficConfig, simulate)
    tenants = (TenantSpec("acme", "zamba2-1.2b"),
               TenantSpec("globex", "zamba2-1.2b"))
    traffic = TrafficConfig(n_requests=n_requests, rate_rps=4.0, seed=3)
    rram = RRAMBackendConfig(enabled=True, device="ag-si", k_iters=3)
    rows: List[Dict] = []
    for label, rel in (("off", None),
                       ("thr-0.05", ReliabilityConfig(refresh_threshold=0.05)),
                       ("thr-1.0", ReliabilityConfig(refresh_threshold=1.0))):
        res = simulate(ServingConfig(tenants=tenants, traffic=traffic,
                                     rram=rram, run_model=False,
                                     seed=0, reliability=rel))
        row = {"name": f"reliability/serving/{label}",
               "joules_per_token": f"{res.summary['joules_per_token']:.3e}",
               "p99_latency_s": round(res.summary["p99_latency_s"], 3)}
        rel_sum = res.summary.get("reliability")
        if rel_sum is not None:
            row.update({
                "refreshes": rel_sum["refreshes"],
                "refresh_energy_j": f"{rel_sum['refresh_energy_j']:.3e}",
                "refresh_stall_s": round(rel_sum["refresh_stall_s"], 2),
                "mean_predicted": f"{rel_sum['mean_predicted_residual']:.3e}",
                "max_predicted": f"{rel_sum['max_predicted_residual']:.3e}"})
        rows.append(row)
    return rows


def run(quick: bool = True, smoke: bool = False) -> List[Dict]:
    n = 256
    devices = DEVICES_SMOKE if smoke else (DEVICES_QUICK if quick
                                           else DEVICES_FULL)
    rows: List[Dict] = []
    for dev in devices:
        rows.extend(_aging_rows(dev, n))
    rows.append(_ft_local_row(128 if smoke else n))
    rows.append(_ft_distributed_row(128))
    if not smoke:
        rows.extend(_serving_rows(16 if quick else 48))
    _write_json(rows, quick or smoke,
                "smoke" if smoke else ("quick" if quick else "full"))
    return rows


def _out_path(quick: bool) -> str:
    if quick:
        return os.path.join(tempfile.gettempdir(),
                            "BENCH_reliability.smoke.json")
    return OUT_JSON


def _write_json(rows: List[Dict], quick: bool, mode: str) -> str:
    payload = {
        "bench": "reliability",
        "mode": mode,
        "metadata": run_metadata(),
        "rows": rows,
    }
    out = _out_path(quick)
    with open(out, "w") as f:
        json.dump(payload, f, indent=2)
        f.write("\n")
    return out


def _assert_contracts(rows: List[Dict]) -> None:
    # Contract A: unrefreshed aged solve misses tolerance; tile-selective
    # refresh restores it to within 2x fresh at strictly less write energy
    # than a full reprogram.
    none_row = next(r for r in rows
                    if r["name"] == "reliability/age/epiram/none")
    tiles_row = next(r for r in rows
                     if r["name"] == "reliability/age/epiram/tiles")
    fresh = float(tiles_row["fresh_rel"])
    assert float(none_row["solve_rel"]) > AGED_TOL, none_row
    assert float(tiles_row["solve_rel"]) <= 2.0 * fresh, (tiles_row, fresh)
    assert tiles_row["refresh_energy_j"] < tiles_row["full_rewrite_j"], \
        tiles_row
    assert 0 < tiles_row["tiles_refreshed"] < tiles_row["tiles_total"], \
        tiles_row
    # Contract B: the distributed mid-solve fault is detected and recovered
    # through CheckpointManager to a converged solve.
    dist = next(r for r in rows
                if r["name"] == "reliability/ft/cg/distributed-2x4")
    assert dist["converged"] and dist["restores"] >= 1, dist
    assert dist["devices"] == 8, dist


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--smoke", action="store_true",
                    help="one device, no serving sweep (CI fast job); writes "
                         "to the temp dir")
    ap.add_argument("--full", action="store_true",
                    help="all four devices + serving sweep; refreshes the "
                         "checked-in JSON")
    args = ap.parse_args()
    rows = run(quick=not args.full, smoke=args.smoke)
    for r in rows:
        detail = ", ".join(f"{k}={v}" for k, v in r.items() if k != "name")
        print(f"{r['name']}: {detail}")
    print(f"wrote {_out_path(not args.full)}")
    _assert_contracts(rows)
    print("contracts A (tile refresh) and B (distributed recovery): OK")


if __name__ == "__main__":
    main()
