"""Paper Fig. 5 (strong scaling): fixed system (8x8 tile, 1024^2 cells =
8192^2 capacity), problem size swept over the Supplementary-A matrix set
(66 .. 65,025; surrogates with the published kappa / norms, DESIGN.md).

Problems beyond ~16k^2 never materialize: the streamed engine generates
capacity-sized blocks on demand (the paper's virtualization, with the
reassignment normalization from section 2.3.2).
"""
from __future__ import annotations

from typing import Dict, List

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (CrossbarConfig, MCAGeometry, corrected_mvm,
                        get_device, rel_l2, rel_linf, streamed_corrected_mvm)
from repro.core.matrices import ImplicitBandedMatrix, paper_matrix
from repro.core.virtualization import reassignment_count

GEOM = MCAGeometry(tile_rows=8, tile_cols=8, cell_rows=1024, cell_cols=1024)

MATS_SMALL = ["bcsstk02", "wang2", "add32", "c-38"]
MATS_BIG = [("dubcova1", 16129), ("helm3d01", 32226), ("dubcova2", 65025)]


def run(quick: bool = True) -> List[Dict]:
    device = get_device("taox-hfox")
    cfg = CrossbarConfig(device=device, geom=GEOM, k_iters=5, ec=True)
    rows: List[Dict] = []
    key = jax.random.PRNGKey(11)

    for name in (MATS_SMALL if quick else MATS_SMALL):
        a = jnp.asarray(paper_matrix(name), jnp.float32)
        n = a.shape[0]
        x = jax.random.normal(jax.random.fold_in(key, n), (n,))
        b = a @ x
        y, stats = jax.jit(lambda k: corrected_mvm(a, x, k, cfg))(
            jax.random.fold_in(key, 2 * n))
        norm = max(reassignment_count(n, n, GEOM), 1)
        rows.append({
            "name": f"strong/{name}/n{n}",
            "eps_l2": float(rel_l2(y, b)), "eps_linf": float(rel_linf(y, b)),
            "E_w": float(stats.energy_j), "L_w": float(stats.latency_s),
            "E_w_norm": float(stats.energy_j) / norm,
            "L_w_norm": float(stats.latency_s) / norm,
            "reassignments": norm,
        })

    big = MATS_BIG[:1] if quick else MATS_BIG
    cap = GEOM.capacity[0]
    for name, n in big:
        imp = ImplicitBandedMatrix(n=n, cap_m=cap, cap_n=cap, seed=n)
        x = jax.random.normal(jax.random.fold_in(key, n), (n,))
        b = imp.matvec(x)
        y, stats = streamed_corrected_mvm(
            imp.block, x, n, n, jax.random.fold_in(key, 3 * n), cfg)
        norm = max(reassignment_count(n, n, GEOM), 1)
        rows.append({
            "name": f"strong/{name}/n{n}",
            "eps_l2": float(rel_l2(y, b)), "eps_linf": float(rel_linf(y, b)),
            "E_w": float(stats.energy_j), "L_w": float(stats.latency_s),
            "E_w_norm": float(stats.energy_j) / norm,
            "L_w_norm": float(stats.latency_s) / norm,
            "reassignments": norm,
        })
    return rows


if __name__ == "__main__":
    from .common import emit
    emit(run())
