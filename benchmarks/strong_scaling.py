"""Paper Fig. 5 (strong scaling): fixed system (8x8 tile, 1024^2 cells =
8192^2 capacity), problem size swept over the Supplementary-A matrix set
(66 .. 65,025; surrogates with the published kappa / norms, DESIGN.md).

Problems beyond ~16k^2 never materialize: the streamed engine generates
capacity-sized blocks on demand (the paper's virtualization, with the
reassignment normalization from section 2.3.2).
"""
from __future__ import annotations

from typing import Dict, List

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (CrossbarConfig, MCAGeometry, get_device, rel_l2,
                        rel_linf)
from repro.core.matrices import ImplicitBandedMatrix, paper_matrix
from repro.core.virtualization import reassignment_count
from repro.engine import AnalogEngine

GEOM = MCAGeometry(tile_rows=8, tile_cols=8, cell_rows=1024, cell_cols=1024)

MATS_SMALL = ["bcsstk02", "wang2", "add32", "c-38"]
MATS_BIG = [("dubcova1", 16129), ("helm3d01", 32226), ("dubcova2", 65025)]


def run(quick: bool = True) -> List[Dict]:
    device = get_device("taox-hfox")
    cfg = CrossbarConfig(device=device, geom=GEOM, k_iters=5, ec=True)
    rows: List[Dict] = []
    key = jax.random.PRNGKey(11)

    engine = AnalogEngine(cfg)

    def row_from(name, n, A, y, b):
        per_call = A.input_write_stats(batch=1)
        e_w = float(A.write_stats.energy_j) + float(per_call.energy_j)
        l_w = float(A.write_stats.latency_s) + float(per_call.latency_s)
        norm = max(reassignment_count(n, n, GEOM), 1)
        return {
            "name": f"strong/{name}/n{n}",
            "eps_l2": float(rel_l2(y, b)), "eps_linf": float(rel_linf(y, b)),
            "E_w": e_w, "L_w": l_w,
            "E_w_norm": e_w / norm, "L_w_norm": l_w / norm,
            "reassignments": norm,
        }

    for name in (MATS_SMALL if quick else MATS_SMALL):
        a = jnp.asarray(paper_matrix(name), jnp.float32)
        n = a.shape[0]
        x = jax.random.normal(jax.random.fold_in(key, n), (n,))
        b = a @ x
        A = engine.program(a, jax.random.fold_in(key, 2 * n))
        rows.append(row_from(name, n, A, engine.mvm(A, x), b))

    big = MATS_BIG[:1] if quick else MATS_BIG
    cap = GEOM.capacity[0]
    streamed = AnalogEngine(cfg, execution="streamed")
    for name, n in big:
        imp = ImplicitBandedMatrix(n=n, cap_m=cap, cap_n=cap, seed=n)
        x = jax.random.normal(jax.random.fold_in(key, n), (n,))
        b = imp.matvec(x)
        A = streamed.program(imp.block, jax.random.fold_in(key, 3 * n),
                             shape=(n, n))
        rows.append(row_from(name, n, A, streamed.mvm(A, x), b))
    return rows


if __name__ == "__main__":
    from .common import emit
    emit(run())
