"""Paper Fig. 5 (strong scaling): fixed system (8x8 tile, 1024^2 cells =
8192^2 capacity), problem size swept over the Supplementary-A matrix set
(66 .. 65,025; surrogates with the published kappa / norms, DESIGN.md).

Problems beyond ~16k^2 never materialize: the streamed engine generates
capacity-sized blocks on demand (the paper's virtualization, with the
reassignment normalization from section 2.3.2).

The producer-driven distributed-solve sweep (:func:`run_distributed`) is the
headline scale demonstration: a matrix is programmed over a device mesh from
a traceable block producer and SOLVED (CG through ``repro.solvers``) with no
A-sized array ever allocated -- asserted statically per row via
:func:`repro.analysis.memory.max_aval_elements` on the exact jitted MVM.
Full mode runs the >= 65,536^2 case (``resident=False``: every device holds
at most one capacity block of A at a time).

    PYTHONPATH=src python -m benchmarks.strong_scaling --smoke   # CI fast job
    PYTHONPATH=src python -m benchmarks.strong_scaling --full
"""
from __future__ import annotations

import os
# Must precede backend init so the standalone CLI gets a multi-device mesh;
# harmless when another process owner already initialized jax.
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

from typing import Dict, List

import jax
import jax.numpy as jnp
import numpy as np

from repro import solvers
from repro.analysis.memory import max_aval_elements
from repro.core import (CrossbarConfig, MCAGeometry, get_device, rel_l2,
                        rel_linf)
from repro.core.matrices import ImplicitBandedMatrix, paper_matrix
from repro.core.virtualization import reassignment_count
from repro.engine import AnalogEngine
from repro.launch.mesh import make_mesh

GEOM = MCAGeometry(tile_rows=8, tile_cols=8, cell_rows=1024, cell_cols=1024)

MATS_SMALL = ["bcsstk02", "wang2", "add32", "c-38"]
MATS_BIG = [("dubcova1", 16129), ("helm3d01", 32226), ("dubcova2", 65025)]


def run(quick: bool = True) -> List[Dict]:
    device = get_device("taox-hfox")
    cfg = CrossbarConfig(device=device, geom=GEOM, k_iters=5, ec=True)
    rows: List[Dict] = []
    key = jax.random.PRNGKey(11)

    engine = AnalogEngine(cfg)

    def row_from(name, n, A, y, b):
        per_call = A.input_write_stats(batch=1)
        e_w = float(A.write_stats.energy_j) + float(per_call.energy_j)
        l_w = float(A.write_stats.latency_s) + float(per_call.latency_s)
        norm = max(reassignment_count(n, n, GEOM), 1)
        return {
            "name": f"strong/{name}/n{n}",
            "eps_l2": float(rel_l2(y, b)), "eps_linf": float(rel_linf(y, b)),
            "E_w": e_w, "L_w": l_w,
            "E_w_norm": e_w / norm, "L_w_norm": l_w / norm,
            "reassignments": norm,
        }

    for name in (MATS_SMALL if quick else MATS_SMALL):
        a = jnp.asarray(paper_matrix(name), jnp.float32)
        n = a.shape[0]
        x = jax.random.normal(jax.random.fold_in(key, n), (n,))
        b = a @ x
        A = engine.program(a, jax.random.fold_in(key, 2 * n))
        rows.append(row_from(name, n, A, engine.mvm(A, x), b))

    big = MATS_BIG[:1] if quick else MATS_BIG
    cap = GEOM.capacity[0]
    streamed = AnalogEngine(cfg, execution="streamed")
    for name, n in big:
        imp = ImplicitBandedMatrix(n=n, cap_m=cap, cap_n=cap, seed=n)
        x = jax.random.normal(jax.random.fold_in(key, n), (n,))
        b = imp.matvec(x)
        A = streamed.program(imp.block, jax.random.fold_in(key, 3 * n),
                             shape=(n, n))
        rows.append(row_from(name, n, A, streamed.mvm(A, x), b))
    rows += run_distributed(quick=quick)
    return rows


def best_mesh(max_devices: int = 8):
    """Largest (rows, cols) mesh this process can host, (2, 4)-preferred."""
    avail = min(jax.device_count(), max_devices)
    for shape in ((2, 4), (2, 2), (1, 2)):
        if shape[0] * shape[1] <= avail:
            return make_mesh(shape, ("data", "model"))
    return make_mesh((1, 1), ("data", "model"))


def run_distributed(quick: bool = True) -> List[Dict]:
    """Producer-driven distributed solves with a no-A-sized-allocation proof.

    Each row programs an :class:`ImplicitBandedMatrix` over the mesh from its
    traceable block producer and solves ``A x = b`` with CG.  The image never
    materializes globally; ``resident=False`` rows additionally never hold it
    per-device (one capacity block per scan step is the high-water mark,
    reported as ``max_elems`` / asserted ``< n^2``).
    """
    mesh = best_mesh()
    n_dev = mesh.devices.size
    # (n, cap, resident): quick stays sub-second-scale; full adds the paper's
    # >= 65,536^2 case, virtual image (O(one block) per device).
    cases = [(2048, 256, True), (4096, 256, False)] if quick else \
        [(8192, 1024, True), (16384, 1024, False), (65536, 2048, False)]
    rows: List[Dict] = []
    for n, cap, resident in cases:
        geom = MCAGeometry(tile_rows=1, tile_cols=1,
                           cell_rows=cap, cell_cols=cap)
        cfg = CrossbarConfig(device=get_device("epiram"), geom=geom,
                             k_iters=5, ec=True)
        eng = AnalogEngine(cfg, execution="distributed", mesh=mesh)
        imp = ImplicitBandedMatrix(n=n, cap_m=cap, cap_n=cap, seed=n)
        key = jax.random.fold_in(jax.random.PRNGKey(5), n)
        A = eng.program(imp.block, key, shape=(n, n), resident=resident)
        b = jnp.ones((n,), jnp.float32)
        # Static proof BEFORE solving: the largest array the jitted MVM can
        # ever hold.  Virtual handles bound far below A (one capacity block
        # per scan step -- the no-A-sized-allocation claim); resident handles
        # are allowed exactly the mesh-sharded conductance image (the
        # simulated hardware state) and nothing larger.
        max_elems = max_aval_elements(
            lambda x, k: eng.mvm(A, x, key=k),
            jax.ShapeDtypeStruct((n,), jnp.float32),
            jax.ShapeDtypeStruct(key.shape, key.dtype))
        if resident:
            assert max_elems <= A.at_blocks.size, (max_elems, A.at_blocks.size)
        else:
            assert max_elems < n * n, (max_elems, n * n)
        res = solvers.cg(A, b, tol=5e-3, maxiter=12, key=key)
        led = res.ledger
        rows.append({
            "name": f"strong/dist{'_virtual' if not resident else ''}/n{n}",
            "devices": n_dev,
            "iters": res.iterations,
            "converged": bool(res.converged),
            "resid": res.final_residual,
            "max_elems": max_elems,
            "A_elems": n * n,
            "E_write_J": led.write_energy_j,
            "E_iters_J": led.iteration_energy_j,
        })
    rows += run_pdhg_virtual(quick=quick)
    return rows


def run_pdhg_virtual(quick: bool = True) -> List[Dict]:
    """The companion-paper workload at paper scale: a feasible LP whose
    >= 65,536^2 constraint matrix (full mode; 4096^2 quick) exists only as a
    traceable producer, solved by PDHG over the mesh with ``resident=False``
    -- so BOTH the forward and the transposed corrected MVM re-encode blocks
    inside their scans and no A-sized array is ever allocated (statically
    asserted on each direction's exact jitted MVM).  PDHG is O(1/k): the
    full-scale row runs a fixed handful of iterations and reports the KKT
    drop from the entry residual rather than converging to tolerance."""
    mesh = best_mesh()
    n, cap, maxiter = (4096, 256, 40) if quick else (65536, 2048, 6)
    geom = MCAGeometry(tile_rows=1, tile_cols=1, cell_rows=cap, cell_cols=cap)
    cfg = CrossbarConfig(device=get_device("epiram"), geom=geom,
                         k_iters=5, ec=True)
    eng = AnalogEngine(cfg, execution="distributed", mesh=mesh)
    imp = ImplicitBandedMatrix(n=n, cap_m=cap, cap_n=cap, seed=n + 1)
    key = jax.random.fold_in(jax.random.PRNGKey(6), n)
    A = eng.program(imp.block, key, shape=(n, n), resident=False)
    max_fwd = max_aval_elements(
        lambda x, k: eng.mvm(A, x, key=k),
        jax.ShapeDtypeStruct((n,), jnp.float32),
        jax.ShapeDtypeStruct(key.shape, key.dtype))
    max_t = max_aval_elements(
        lambda y, k: eng.rmvm(A, y, key=k),
        jax.ShapeDtypeStruct((n,), jnp.float32),
        jax.ShapeDtypeStruct(key.shape, key.dtype))
    assert max(max_fwd, max_t) < n * n, (max_fwd, max_t, n * n)
    # Feasible-by-construction LP from O(n) vectors: complementary (x*, s)
    # split of a deterministic pattern, b/c via the producer's exact matvec.
    idx = jnp.arange(n, dtype=jnp.float32)
    u = jnp.sin(0.37 * idx)
    x_star = jnp.maximum(u, 0.0)
    s = jnp.maximum(-u, 0.0)
    y_star = jnp.cos(0.23 * idx) / 8.0
    b = imp.matvec(x_star)
    c = imp.rmatvec(y_star) + s
    # power_iters=4 keeps the full-scale setup at 8 MVMs; the banded
    # surrogate's norm estimate converges in a few steps.
    res = solvers.pdhg(A, b, c, tol=1e-3, maxiter=maxiter, key=key,
                       power_iters=4)
    led = res.ledger
    return [{
        "name": f"strong/pdhg_virtual/n{n}",
        "devices": mesh.devices.size,
        "iters": res.iterations,
        "converged": bool(res.converged),
        "kkt0": res.initial_residual,
        "kkt": res.final_residual,
        "max_elems": max(max_fwd, max_t),
        "A_elems": n * n,
        "E_write_J": led.write_energy_j,
        "E_iters_J": led.iteration_energy_j,
    }]


if __name__ == "__main__":
    import argparse

    from .common import emit
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--smoke", action="store_true",
                    help="CI fast mode: only the quick distributed-solve "
                         "sweep (multi-device when XLA host devices are up)")
    ap.add_argument("--full", action="store_true",
                    help="paper-scale sweep incl. the 65,536^2 virtual solve")
    args = ap.parse_args()
    if args.smoke:
        emit(run_distributed(quick=True))
    else:
        emit(run(quick=not args.full))
