"""Paper Fig. 4 (weak scaling): fixed problem (add32 surrogate, 4960^2),
fixed 8x8 MCA tile, array cell size swept 32^2 .. 1024^2.

Expected (paper section 2.3.1): relative error stays flat (~1e-3..4e-2 band);
small cells pay heavily in write energy/latency because virtualization
reassigns each MCA ceil(4960/(8*cell))^2 times; >=512^2 cells execute in one
assignment.
"""
from __future__ import annotations

from typing import Dict, List

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (CrossbarConfig, MCAGeometry, get_device,
                        rel_l2, rel_linf)
from repro.core.matrices import make_spd_with_condition
from repro.core.virtualization import reassignment_count
from repro.engine import AnalogEngine

N = 4960   # add32 dimension


def run(quick: bool = True) -> List[Dict]:
    cells = [32, 128, 512, 1024] if quick else [32, 64, 128, 256, 512, 1024]
    devices = ["taox-hfox", "epiram"] if quick else [
        "epiram", "ag-si", "alox-hfo2", "taox-hfox"]
    a = jnp.asarray(
        make_spd_with_condition(N, kappa=1.366769e2, norm2=5.749318e-2),
        jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(3), (N,))
    b = a @ x
    rows = []
    for cell in cells:
        geom = MCAGeometry(tile_rows=8, tile_cols=8,
                           cell_rows=cell, cell_cols=cell)
        for dev in devices:
            cfg = CrossbarConfig(device=get_device(dev), geom=geom,
                                 k_iters=5, ec=True)
            engine = AnalogEngine(cfg)
            A = engine.program(a, jax.random.PRNGKey(cell))
            y = engine.mvm(A, x)
            per_call = A.input_write_stats(batch=1)
            rows.append({
                "name": f"weak/{dev}/cell{cell}",
                "eps_l2": float(rel_l2(y, b)),
                "eps_linf": float(rel_linf(y, b)),
                "E_w": float(A.write_stats.energy_j) + float(per_call.energy_j),
                "L_w": float(A.write_stats.latency_s) + float(per_call.latency_s),
                "reassignments": reassignment_count(N, N, geom),
            })
    return rows


if __name__ == "__main__":
    from .common import emit
    emit(run())
