"""Paper Fig. 4 (weak scaling): fixed problem (add32 surrogate, 4960^2),
fixed 8x8 MCA tile, array cell size swept 32^2 .. 1024^2.

Expected (paper section 2.3.1): relative error stays flat (~1e-3..4e-2 band);
small cells pay heavily in write energy/latency because virtualization
reassigns each MCA ceil(4960/(8*cell))^2 times; >=512^2 cells execute in one
assignment.

:func:`run_distributed` adds the mesh dimension of weak scaling: a FIXED
per-device window of the capacity-block grid, mesh grown 1 -> 4 -> 8 devices
(problem size grows with it), each point programmed from a traceable block
producer -- the matrix never materializes -- and driven through a distributed
CG solve.  Per-MVM wall time should stay ~flat while n grows, the signature
of producer-driven weak scaling.

    PYTHONPATH=src python -m benchmarks.weak_scaling --smoke     # CI fast job
"""
from __future__ import annotations

import os
# Must precede backend init so the standalone CLI gets a multi-device mesh.
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

from typing import Dict, List

import jax
import jax.numpy as jnp
import numpy as np

from repro import solvers
from repro.core import (CrossbarConfig, MCAGeometry, get_device,
                        rel_l2, rel_linf)
from repro.core.matrices import ImplicitBandedMatrix, make_spd_with_condition
from repro.core.virtualization import reassignment_count
from repro.engine import AnalogEngine
from repro.launch.mesh import make_mesh

from .common import time_call

N = 4960   # add32 dimension


def run(quick: bool = True) -> List[Dict]:
    cells = [32, 128, 512, 1024] if quick else [32, 64, 128, 256, 512, 1024]
    devices = ["taox-hfox", "epiram"] if quick else [
        "epiram", "ag-si", "alox-hfo2", "taox-hfox"]
    a = jnp.asarray(
        make_spd_with_condition(N, kappa=1.366769e2, norm2=5.749318e-2),
        jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(3), (N,))
    b = a @ x
    rows = []
    for cell in cells:
        geom = MCAGeometry(tile_rows=8, tile_cols=8,
                           cell_rows=cell, cell_cols=cell)
        for dev in devices:
            cfg = CrossbarConfig(device=get_device(dev), geom=geom,
                                 k_iters=5, ec=True)
            engine = AnalogEngine(cfg)
            A = engine.program(a, jax.random.PRNGKey(cell))
            y = engine.mvm(A, x)
            per_call = A.input_write_stats(batch=1)
            rows.append({
                "name": f"weak/{dev}/cell{cell}",
                "eps_l2": float(rel_l2(y, b)),
                "eps_linf": float(rel_linf(y, b)),
                "E_w": float(A.write_stats.energy_j) + float(per_call.energy_j),
                "L_w": float(A.write_stats.latency_s) + float(per_call.latency_s),
                "reassignments": reassignment_count(N, N, geom),
            })
    rows += run_distributed(quick=quick)
    return rows


def run_distributed(quick: bool = True) -> List[Dict]:
    """Mesh weak scaling: ~fixed per-device block window, growing device grid.

    Each mesh point programs an :class:`ImplicitBandedMatrix` over the mesh
    from its block producer and runs one warm distributed MVM plus a CG
    solve.  ``us_per_call`` is the per-MVM wall time; compare it between
    points with equal ``blocks_per_dev`` (the square-grid constraint makes an
    exactly fixed window impossible at 8 devices, so the 2x4 point carries
    HALF the window -- its time dropping ~2x is the expected reading, not
    super-linear scaling; every row reports ``blocks_per_dev`` for this).
    """
    cap = 128 if quick else 512
    # (mesh shape, square block-grid edge): grid g x g with g chosen so every
    # device owns an equal window (g % rows == 0, g % cols == 0).  1 -> 4
    # devices holds 4 blocks/device; 8 devices halves it (see docstring).
    points = [((1, 1), 2), ((2, 2), 4), ((2, 4), 4)]
    avail = jax.device_count()
    rows: List[Dict] = []
    for shape, g in points:
        n_dev = shape[0] * shape[1]
        if n_dev > avail:
            continue
        mesh = make_mesh(shape, ("data", "model"))
        n = g * cap
        geom = MCAGeometry(tile_rows=1, tile_cols=1,
                           cell_rows=cap, cell_cols=cap)
        cfg = CrossbarConfig(device=get_device("epiram"), geom=geom,
                             k_iters=5, ec=True)
        eng = AnalogEngine(cfg, execution="distributed", mesh=mesh)
        imp = ImplicitBandedMatrix(n=n, cap_m=cap, cap_n=cap, seed=g)
        key = jax.random.fold_in(jax.random.PRNGKey(7), n_dev)
        A = eng.program(imp.block, key, shape=(n, n))
        x = jax.random.normal(jax.random.fold_in(key, 1), (n,))
        k_mvm = jax.random.fold_in(key, 2)
        us = time_call(lambda: eng.mvm(A, x, key=k_mvm),
                       iters=1 if quick else 3)
        res = solvers.cg(A, jnp.ones((n,), jnp.float32), tol=5e-3,
                         maxiter=12, key=key)
        rows.append({
            "name": f"weak/dist/mesh{shape[0]}x{shape[1]}/n{n}",
            "us_per_call": us,
            "devices": n_dev,
            "blocks_per_dev": (g * g) // n_dev,
            "iters": res.iterations,
            "converged": bool(res.converged),
            "resid": res.final_residual,
        })
    return rows


if __name__ == "__main__":
    import argparse

    from .common import emit
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--smoke", action="store_true",
                    help="CI fast mode: only the distributed mesh sweep")
    ap.add_argument("--full", action="store_true")
    args = ap.parse_args()
    if args.smoke:
        emit(run_distributed(quick=True))
    else:
        emit(run(quick=not args.full))
