"""Framework-level step benchmarks (beyond-paper): wall time of one train
step / decode token on CPU for reduced configs, digital vs RRAM-analog
backend -- demonstrates the paper's technique as an LM serving mode and gives
a regression-tracked number for the step pipeline itself.
"""
from __future__ import annotations

from typing import Dict, List

import jax
import jax.numpy as jnp

from repro.configs import get_arch, model_module
from repro.configs.base import RRAMBackendConfig, TrainConfig
from repro.models import params as PM
from repro.models.common import Runtime
from repro.models.rram import program_rram
from repro.train.train_loop import make_train_step
from repro.train.optimizer import adamw_init
from .common import time_call

ARCHS = ["qwen3-1.7b", "rwkv6-1.6b", "mixtral-8x7b"]


def run(quick: bool = True) -> List[Dict]:
    rows = []
    b, t = (2, 32) if quick else (4, 128)
    for arch_name in ARCHS:
        arch = get_arch(arch_name)
        cfg = arch.reduced()
        mod = model_module(cfg)
        prm = PM.materialize(mod.init_specs(cfg), jax.random.PRNGKey(0))
        tokens = jax.random.randint(jax.random.PRNGKey(1), (b, t), 0, cfg.vocab)
        batch = {"tokens": tokens, "labels": tokens}

        step = jax.jit(make_train_step(mod, cfg, TrainConfig()))
        opt = adamw_init(prm)
        us = time_call(lambda: step(prm, opt, batch))
        rows.append({"name": f"lm/{arch_name}/train_step", "us_per_call": round(us),
                     "tokens_per_s": round(b * t / (us * 1e-6))})

        rt = Runtime()
        _, caches = mod.prefill(prm, batch, cfg, rt, 64) \
            if cfg.family != "rwkv6" else mod.prefill(prm, batch, cfg, rt)
        tok = tokens[:, :1]
        dstep = jax.jit(lambda p, tk, c: mod.decode_step(p, tk, c, cfg, rt))
        us = time_call(lambda: dstep(prm, tok, caches))
        rows.append({"name": f"lm/{arch_name}/decode_step",
                     "us_per_call": round(us),
                     "tokens_per_s": round(b / (us * 1e-6))})

    # RRAM analog serving backend (the paper's technique in the LM stack).
    arch = get_arch("qwen3-1.7b")
    cfg = arch.reduced()
    mod = model_module(cfg)
    prm = PM.materialize(mod.init_specs(cfg), jax.random.PRNGKey(0))
    rcfg = RRAMBackendConfig(enabled=True, cell_rows=32, cell_cols=32, k_iters=5)
    prm_rram, wstats = program_rram(prm, rcfg, jax.random.PRNGKey(2))
    rt = Runtime(rram=rcfg, key=jax.random.PRNGKey(3))
    tokens = jax.random.randint(jax.random.PRNGKey(1), (b, 8), 0, cfg.vocab)
    _, caches = mod.prefill(prm_rram, {"tokens": tokens}, cfg, rt, 64)
    dstep = jax.jit(lambda p, tk, c: mod.decode_step(p, tk, c, cfg, rt))
    us = time_call(lambda: dstep(prm_rram, tokens[:, :1], caches))
    rows.append({"name": "lm/qwen3-1.7b/decode_step_rram_ec",
                 "us_per_call": round(us),
                 "program_energy_j": f"{float(wstats.energy_j):.3e}",
                 "program_latency_s": f"{float(wstats.latency_s):.3e}"})
    return rows


if __name__ == "__main__":
    from .common import emit
    emit(run())
