"""LSQR/LSMR least-squares convergence: device x EC x algorithm sweep.

The rectangular-workload companion to ``pdhg_convergence``: overdetermined
``min ||A x - b||`` problems with an inconsistent RHS (nonzero optimal
residual) solved by :func:`repro.solvers.lsqr` and
:func:`repro.solvers.lsmr` against one programmed image -- every
Golub-Kahan bidiagonalization step is one corrected forward MVM plus one
corrected TRANSPOSED MVM (``rmatvec``), both billed to the ledger.
Reported per row:

  * ``iters``      -- bidiagonalization iterations to the residual tol;
  * ``normal_res`` -- ||A^T (A x - b)|| / (||A|| ||A x - b||), the
                      least-squares optimality certificate (digital recompute);
  * ``x_gap``      -- rel_l2(x, x_direct) against the dense
                      ``jnp.linalg.lstsq`` solution, the acceptance metric
                      (<= the gate for the precision device with EC);
  * ``E_write_J`` / ``E_iters_J`` -- one-time write vs per-iteration energy
                      (forward + transposed input writes).

Results land in ``BENCH_lstsq_convergence.json`` (full runs refresh the
checked-in baseline at the repo root; smoke/quick runs write to the temp
dir), with the initialized device count + ``XLA_FLAGS`` recorded in the
metadata block.

    PYTHONPATH=src python -m benchmarks.lstsq_convergence            # quick
    PYTHONPATH=src python -m benchmarks.lstsq_convergence --smoke    # CI
    PYTHONPATH=src python -m benchmarks.lstsq_convergence --full
"""
from __future__ import annotations

import argparse
import json
import os
import tempfile
from typing import Dict, List

import jax
import jax.numpy as jnp

from repro import solvers
from repro.core import CrossbarConfig, MCAGeometry, get_device, rel_l2
from repro.engine import AnalogEngine

from .common import run_metadata

OUT_JSON = os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "BENCH_lstsq_convergence.json")

# (m, n, cell, tol, maxiter)
CASE_SMOKE = (96, 64, 32, 1e-4, 60)
CASE_QUICK = (192, 128, 64, 5e-5, 150)
CASE_FULL = (512, 256, 64, 2e-5, 400)

DEVICES_QUICK = ["epiram", "taox-hfox"]
DEVICES_FULL = ["epiram", "ag-si", "alox-hfo2", "taox-hfox"]

ALGOS = {"lsqr": solvers.lsqr, "lsmr": solvers.lsmr}


def _normal_residual(a, x, b) -> float:
    """||A^T r|| / (||A||_F ||r||): the LS optimality certificate."""
    r = a @ x - b
    denom = float(jnp.linalg.norm(a)) * float(jnp.linalg.norm(r)) + 1e-30
    return float(jnp.linalg.norm(a.T @ r)) / denom


def _row(name: str, res, a, b, x_direct) -> Dict:
    led = res.ledger
    return {
        "name": name,
        "iters": res.iterations,
        "converged": bool(res.converged),
        "residual": res.final_residual,
        "normal_res": _normal_residual(a, res.x, b),
        "x_gap": float(rel_l2(res.x, x_direct)),
        "mvms": led.mvms,
        "mvms_t": led.mvms_t,
        "E_write_J": led.write_energy_j,
        "E_iters_J": led.iteration_energy_j,
    }


def _solve_case(algo: str, device: str, ec: bool, a, b, x_direct,
                tol, maxiter, cell) -> Dict:
    geom = MCAGeometry(tile_rows=1, tile_cols=1,
                       cell_rows=cell, cell_cols=cell)
    cfg = CrossbarConfig(device=get_device(device), geom=geom, k_iters=5,
                         ec=ec)
    engine = AnalogEngine(cfg)
    key = jax.random.PRNGKey(3)
    A = engine.program(a, key)
    res = ALGOS[algo](A, b, tol=tol, maxiter=maxiter, key=key)
    return _row(f"{algo}/{device}/{'ec' if ec else 'raw'}", res, a, b,
                x_direct)


def run(quick: bool = True, smoke: bool = False) -> List[Dict]:
    m, n, cell, tol, maxiter = CASE_SMOKE if smoke else \
        (CASE_QUICK if quick else CASE_FULL)
    devices = DEVICES_QUICK if (quick or smoke) else DEVICES_FULL
    key = jax.random.PRNGKey(17)
    ka, kx, kr = jax.random.split(key, 3)
    # Well-conditioned overdetermined system with an INCONSISTENT RHS:
    # b = A x* + noise, so the optimal residual is nonzero and the normal
    # equations (not ||r|| = 0) certify optimality.
    a = jax.random.normal(ka, (m, n), jnp.float32) / jnp.sqrt(jnp.float32(m))
    x_star = jax.random.normal(kx, (n,), jnp.float32)
    b = a @ x_star + 0.1 * jax.random.normal(kr, (m,), jnp.float32)
    x_direct = jnp.linalg.lstsq(a, b)[0]

    rows = []
    for algo in ALGOS:
        digital = ALGOS[algo](a, b, tol=tol, maxiter=maxiter)
        drow = _row(f"{algo}/digital/m{m}n{n}", digital, a, b, x_direct)
        drow["E_write_J"] = 0.0
        drow["E_iters_J"] = 0.0
        rows.append(drow)
    for device in devices:
        for algo in ALGOS:
            rows.append(_solve_case(algo, device, True, a, b, x_direct,
                                    tol, maxiter, cell))
    # EC off on the precision device: shows what tier-1+2 correction buys
    rows.append(_solve_case("lsqr", devices[0], False, a, b, x_direct,
                            tol, maxiter, cell))
    _write_json(rows, quick or smoke, "smoke" if smoke else
                ("quick" if quick else "full"))
    return rows


def _out_path(quick: bool) -> str:
    if quick:
        return os.path.join(tempfile.gettempdir(),
                            "BENCH_lstsq_convergence.smoke.json")
    return OUT_JSON


def _write_json(rows: List[Dict], quick: bool, mode: str) -> str:
    payload = {
        "bench": "lstsq_convergence",
        "mode": mode,
        "metadata": run_metadata(),
        "rows": rows,
    }
    out = _out_path(quick)
    with open(out, "w") as f:
        json.dump(payload, f, indent=2)
        f.write("\n")
    return out


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--smoke", action="store_true",
                    help="tiny system / loose tol (CI fast job); writes to "
                         "the temp dir")
    ap.add_argument("--full", action="store_true",
                    help="paper-scale system + all four devices; refreshes "
                         "the checked-in JSON")
    args = ap.parse_args()
    rows = run(quick=not args.full, smoke=args.smoke)
    for r in rows:
        print(f"{r['name']}: {r['iters']} iters, residual "
              f"{r['residual']:.1e}, normal_res {r['normal_res']:.1e}, "
              f"x_gap {r['x_gap']:.1e}, E_iters {r['E_iters_J']:.2e} J")
    print(f"wrote {_out_path(not args.full)}")
    # CI contract: the precision device with EC recovers the dense
    # ``jnp.linalg.lstsq`` solution.  Analog read noise perturbs the
    # bidiagonalization, so the gate sits an order above the solve tol.
    ec_row = next(r for r in rows if r["name"].startswith("lsqr/epiram/ec"))
    assert ec_row["x_gap"] <= 5e-3, ec_row


if __name__ == "__main__":
    main()
